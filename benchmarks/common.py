"""Shared benchmark helpers: engine configs mirroring the paper's setups,
wall-clock measurement, CSV output."""
from __future__ import annotations

import csv
import os
import time

import jax

from repro.core import engine
from repro.core.types import (
    EngineConfig, PlatformModel, SSDConfig, WorkloadConfig,
)

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# The paper's devices.
D7_PS1010 = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                      num_blocks=1 << 14)
FUTURE_40M = SSDConfig(name="future-40m", t_max_iops=40e6, l_min_us=30.0,
                       n_instances=512, num_blocks=1 << 14)


def nvmevirt_cfg(**kw) -> EngineConfig:
    """Baseline NVMeVirt: 1 dispatcher, 32 workers, per-request timing,
    CPU-thread data path, no coalescing."""
    base = dict(
        num_sqs=32, sq_depth=1024, fetch_width=64, num_units=1,
        workers_per_unit=32, frontend="centralized", mode="per_request",
        coalesced=False, dsa_fetch=False, batched_datapath=False,
        emulate_data=False,
        num_bufs=1 << 10,
    )
    base.update(kw)
    return EngineConfig(**base)


def swarmio_cfg(**kw) -> EngineConfig:
    """SwarmIO: 16 service units (1 dispatcher + 1 worker + DSA each),
    aggregated timing, coalesced fetching, batched async DSA offload."""
    base = dict(
        num_sqs=32, sq_depth=1024, fetch_width=256, num_units=16,
        workers_per_unit=1, frontend="distributed", mode="aggregated",
        coalesced=True, batched_datapath=True, emulate_data=False,
        num_bufs=1 << 10,
    )
    base.update(kw)
    return EngineConfig(**base)


def jit_warmup():
    """One warmup invocation before any timed region.

    Pays the one-time costs a first jit call mixes into its wall-clock —
    backend initialization, compiler warm paths, dispatch machinery — so
    subsequent per-figure timings measure compile+run of *their* programs
    only, not cold-start noise. (Per-config compiles still happen on each
    figure's first call; the wall-clock harnesses time around those with
    their own explicit warmup round.)
    """
    cfg = swarmio_cfg()
    wl = WorkloadConfig(io_depth=8)
    st = engine.init_state(cfg, FUTURE_40M, wl)
    out = engine.make_runner(cfg, FUTURE_40M, wl, PlatformModel(), 1)(st)
    jax.block_until_ready(out.metrics.completed)


def run_engine(cfg, ssd, wl, plat=None, rounds=48, num_devices=1):
    """Run the engine to completion. ``wl`` may be a legacy WorkloadConfig
    or any generator from repro.workloads; ``num_devices > 1`` emulates a
    vmapped M-drive array (leaves gain a leading device axis)."""
    out = engine.simulate(
        cfg, ssd, wl, plat, rounds=rounds, num_devices=num_devices
    )
    jax.block_until_ready(out.metrics.completed)
    return out


def wallclock_engine(cfg, ssd, wl, plat=None, rounds=24, reps=3):
    """Wall-clock engine throughput (requests processed per second of real
    time) — the paper's emulation-speed axis."""
    plat = plat or PlatformModel()
    st = engine.init_state(cfg, ssd, wl)
    runner = engine.make_runner(cfg, ssd, wl, plat, rounds)
    out = runner(st)  # compile + warm
    jax.block_until_ready(out.metrics.completed)
    best = float("inf")
    completed = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        out = runner(st)
        jax.block_until_ready(out.metrics.completed)
        dt = time.perf_counter() - t0
        best = min(best, dt)
        completed = float(out.metrics.completed)
    return completed / best, out


def write_csv(name: str, header: list, rows: list):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path
