"""Emulator wall-clock speed benchmark (the paper's headline axis).

Times jit-compiled steady-state engine rounds — ``make_runner`` /
``make_array_runner`` — with ``time.perf_counter`` after an explicit
warmup/compile invocation, and reports **emulated requests per
wall-second** across three configs:

  * ``local_1drive``  — one SwarmIO-config drive at the future-40M target;
  * ``array_4drive``  — the same drive vmapped into a 4-drive array;
  * ``remote_qos``    — one remote drive behind a switched fabric with
                        two WFQ tenant classes (the heaviest pipeline).

Each config runs three variants:

  * ``seed``             — the pre-optimization path (no buffer donation,
                           per-stage sorts and segmented reductions:
                           ``use_sort_plan=False, use_compaction=False``);
  * ``optimized``        — donated state buffers + the epoch sort plan +
                           the PR-8 compaction paths (sort-free timing
                           layout, counting-sorted flash/lanes, block CQ
                           ranks, fused ring scatters);
  * ``optimized_pallas`` — optimized plus the Pallas segmented-scan
                           queueing core (``use_pallas_segscan=True``).

Every variant is timed for ``--reps`` repetitions *post-warmup*, chaining
the state through (``st = runner(st)``) so donation is observable; each
rep records its own wall seconds and requests retired. Each (config,
variant) gets its own two-invocation warmup — compile plus one dispatch
on already-device-resident state — and rep 0 is sanity-checked at
``<= 3x`` the rep median (a violation means compile or retrace leaked
into the timed region; it is recorded in the JSON and warned about, not
fatal). Results persist to ``BENCH_emulator_speed.json`` at the repo
root (schema documented in the README's "Emulator speed" section) and a
CSV summary row per config/variant flows through ``benchmarks/run.py``.

    PYTHONPATH=src python -m benchmarks.emulator_speed [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import engine
from repro.core.types import FabricConfig, PlatformModel, WorkloadConfig
from repro.workloads import MultiTenant

SCHEMA = "emulator_speed/v1"
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_emulator_speed.json",
)

# variant name -> (EngineConfig field overrides, donate buffers?)
VARIANTS = [
    (
        "seed",
        dict(
            use_sort_plan=False, use_compaction=False,
            use_pallas_segscan=False,
        ),
        False,
    ),
    (
        "optimized",
        dict(
            use_sort_plan=True, use_compaction=True,
            use_pallas_segscan=False,
        ),
        True,
    ),
    (
        "optimized_pallas",
        dict(
            use_sort_plan=True, use_compaction=True,
            use_pallas_segscan=True,
        ),
        True,
    ),
]


def _configs(quick: bool):
    rounds = 6 if quick else 24
    # The remote fabric adds whole-RTT + MTU-timeout latency, so the
    # first completions land several rounds after submission; keep the
    # per-invocation round count above that bubble even in --quick so
    # every timed rep retires work.
    remote_rounds = 24
    wl = WorkloadConfig(io_depth=256)
    fab = FabricConfig(
        remote=True,
        tx_bytes_per_us=30_000.0, rx_bytes_per_us=30_000.0,
        rtt_us=2.0, wire_txn_us=0.2, mtu_batch=8, mtu_timeout_us=5.0,
        switch_bytes_per_us=60_000.0, switch_fanin=4,
        qos_weights=(2.0, 1.0),
    )
    mt = MultiTenant(io_depth=256, tenant_read_frac=(1.0, 0.0))
    return [
        dict(name="local_1drive", cfg=C.swarmio_cfg(), ssd=C.FUTURE_40M,
             wl=wl, num_devices=1, rounds=rounds),
        dict(name="array_4drive", cfg=C.swarmio_cfg(), ssd=C.FUTURE_40M,
             wl=wl, num_devices=4, rounds=rounds),
        dict(name="remote_qos", cfg=C.swarmio_cfg(fabric=fab),
             ssd=C.FUTURE_40M, wl=mt, num_devices=1,
             rounds=remote_rounds),
    ]


def _completed(st) -> float:
    """Array-aggregate completed count (device axis summed away)."""
    return float(jnp.sum(st.metrics.completed))


def sanitize_pass(quick: bool = True) -> None:
    """Run every config family once under ``EngineConfig.sanitize``.

    One checkify-instrumented invocation per family (same specs the
    timed benchmark uses) — raises ``checkify.JaxRuntimeError`` on the
    first violated pipeline invariant, so a clean pass certifies the
    benchmarked configs before any timing is trusted. Never timed: the
    functionalized program is a different (slower) XLA program than the
    benchmarked one.
    """
    plat = PlatformModel()
    for spec in _configs(quick):
        cfg, ssd, wl = spec["cfg"], spec["ssd"], spec["wl"]
        m, rounds = spec["num_devices"], spec["rounds"]
        if m == 1:
            st = engine.init_state(cfg, ssd, wl)
            runner = engine.make_runner(
                cfg, ssd, wl, plat, rounds, sanitize=True
            )
        else:
            st = engine.init_array_state(cfg, ssd, wl, m)
            runner = engine.make_array_runner(
                cfg, ssd, wl, plat, rounds, sanitize=True
            )
        jax.block_until_ready(runner(st))
        print(f"  sanitize: {spec['name']} checkify-clean")


def time_variant(cfg, ssd, wl, rounds, num_devices, donate, reps):
    """Warm up one runner, then time ``reps`` chained invocations.

    Returns the per-rep records plus the final state (for virtual-time
    metrics). Two warmup calls pay compile + first dispatch and are never
    timed — the second catches any retrace triggered by the first call's
    *output* avals differing from ``init_state``'s (the historical rep-0
    contamination: a weak-typed leaf in ``Metrics.zero`` silently forced
    a second compile inside the first timed rep). Reps feed each call's
    output back in, which is exactly the regime buffer donation
    optimizes.
    """
    plat = PlatformModel()
    if num_devices == 1:
        st = engine.init_state(cfg, ssd, wl)
        runner = engine.make_runner(cfg, ssd, wl, plat, rounds,
                                    donate=donate)
    else:
        st = engine.init_array_state(cfg, ssd, wl, num_devices)
        runner = engine.make_array_runner(cfg, ssd, wl, plat, rounds,
                                          donate=donate)
    if donate:
        st = engine.unalias(st)
    st = jax.block_until_ready(runner(st))  # warmup 1: compile + run
    st = jax.block_until_ready(runner(st))  # warmup 2: steady-state avals
    rep_records = []
    for _ in range(reps):
        before = _completed(st)
        t0 = time.perf_counter()
        st = runner(st)
        jax.block_until_ready(st)
        dt = time.perf_counter() - t0
        n = _completed(st) - before
        rep_records.append({
            "wall_s": dt,
            "requests": n,
            "req_per_wall_s": n / dt,
        })
    return rep_records, st


def bench(quick: bool = False, reps: int | None = None):
    """Run all configs x variants; write the JSON; return CSV rows."""
    reps = reps if reps is not None else (3 if quick else 5)
    results = []
    rows = []
    for spec in _configs(quick):
        name = spec["name"]
        variants = {}
        for vname, overrides, donate in VARIANTS:
            cfg = spec["cfg"].replace(**overrides)
            recs, st = time_variant(
                cfg, spec["ssd"], spec["wl"], spec["rounds"],
                spec["num_devices"], donate, reps,
            )
            best = max(r["req_per_wall_s"] for r in recs)
            walls = sorted(r["wall_s"] for r in recs)
            median_wall = walls[len(walls) // 2]
            rep0_clean = recs[0]["wall_s"] <= 3.0 * median_wall
            if not rep0_clean:
                print(
                    f"  WARN: {name}/{vname} rep 0 took "
                    f"{recs[0]['wall_s']:.3f}s vs median "
                    f"{median_wall:.3f}s — compile/retrace leaked into "
                    f"the timed region"
                )
            variants[vname] = {
                "donate": donate,
                "use_sort_plan": overrides["use_sort_plan"],
                "use_compaction": overrides["use_compaction"],
                "use_pallas_segscan": overrides["use_pallas_segscan"],
                "reps": recs,
                "req_per_wall_s": best,  # best-of-reps (noise floor)
                "rep0_clean": rep0_clean,  # rep 0 <= 3x median wall_s
                "virtual_miops": float(engine.aggregate_iops(st)) / 1e6,
            }
            rows.append([
                name, vname, spec["rounds"], spec["num_devices"], reps,
                best, variants[vname]["virtual_miops"],
            ])
        seed_rate = variants["seed"]["req_per_wall_s"]

        def _speedup(v):
            # None (JSON null) if the seed retired nothing — a config
            # misconfigured to complete zero requests must not crash
            # the whole matrix.
            return v["req_per_wall_s"] / seed_rate if seed_rate else None

        entry = {
            "name": name,
            "rounds": spec["rounds"],
            "num_devices": spec["num_devices"],
            "variants": variants,
            "speedup_optimized_vs_seed": _speedup(variants["optimized"]),
            "speedup_optimized_pallas_vs_seed":
                _speedup(variants["optimized_pallas"]),
        }
        results.append(entry)
        opt = entry["speedup_optimized_vs_seed"]
        pal = entry["speedup_optimized_pallas_vs_seed"]
        print(
            f"  {name}: seed {seed_rate:,.0f} req/wall-s, optimized "
            f"{f'{opt:.2f}x' if opt else '—'}, "
            f"+pallas {f'{pal:.2f}x' if pal else '—'}"
        )

    payload = {
        "schema": SCHEMA,
        "quick": quick,
        "host": {
            "machine": platform.machine(),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "configs": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"  -> {JSON_PATH}")
    header = ["config", "variant", "rounds", "num_devices", "reps",
              "req_per_wall_s", "virtual_miops"]
    return header, rows


def bench_figure(quick: bool = False):
    """`benchmarks/run.py` entry point (figure-function signature)."""
    return bench(quick=quick)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/reps for CI smoke")
    ap.add_argument("--reps", type=int, default=None,
                    help="timed repetitions per variant (post-warmup)")
    args = ap.parse_args()
    C.jit_warmup()
    header, rows = bench(quick=args.quick, reps=args.reps)
    C.write_csv("emulator_speed", header, rows)


if __name__ == "__main__":
    main()
