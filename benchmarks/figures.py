"""One benchmark per paper figure (Figs. 3-16). Each returns CSV rows and
prints a summary line; run via ``python -m benchmarks.run``.

Virtual-time metrics reproduce the paper's *fidelity* results; wall-clock
metrics reproduce the *emulator speed* results (paper speedups were
measured on Xeon+DSA+H200; ours on this host — the claims map to ratios,
not absolute numbers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as C
from benchmarks import kv_serving as _kv_serving
from repro.core.types import PlatformModel, WorkloadConfig


def _frontend_only_platform():
    """Zero the backend costs to isolate the frontend (paper Fig. 3)."""
    return PlatformModel(
        per_req_map_us=0.0, dsa_desc_issue_us=0.0, dsa_batch_setup_us=0.0,
        dsa_bytes_per_us=1e9, lock_per_req_us=0.085, lock_per_batch_us=0.4,
    )


def fig03_frontend_plateau(quick=False):
    """NVMeVirt frontend throughput plateaus with io_depth (CPU-centric)."""
    rows = []
    depths = [8, 32, 128, 512] if not quick else [8, 128]
    for depth in depths:
        wl = WorkloadConfig(io_depth=depth)
        plat = _frontend_only_platform()
        base = C.run_engine(
            C.nvmevirt_cfg(transport="host", sq_depth=1024),
            C.FUTURE_40M, wl, plat, rounds=32,
        )
        swarm = C.run_engine(
            C.swarmio_cfg(transport="host", sq_depth=1024),
            C.FUTURE_40M, wl, plat, rounds=32,
        )
        rows.append([
            depth,
            float(base.metrics.iops()) / 1e6,
            float(swarm.metrics.iops()) / 1e6,
        ])
    print(f"fig03: centralized plateaus at {max(r[1] for r in rows):.2f} "
          f"MIOPS vs distributed {max(r[2] for r in rows):.2f} MIOPS")
    return ["io_depth", "nvmevirt_miops", "swarmio_miops"], rows


def fig04_per_request_overhead(quick=False):
    """Map/unmap dominates the baseline GPU-initiated copy path."""
    plat = PlatformModel()
    txn = plat.txn_base_us + 512 / plat.link_bytes_per_us
    total = plat.per_req_map_us + txn
    map_frac = plat.per_req_map_us / total
    dsa = plat.dsa_desc_issue_us + plat.dsa_batch_setup_us / 16 \
        + 512 / plat.dsa_bytes_per_us
    rows = [[plat.per_req_map_us, txn, map_frac, dsa, total / dsa]]
    print(f"fig04: map/unmap = {map_frac*100:.1f}% of baseline copy path; "
          f"DSA batched path {total/dsa:.1f}x cheaper")
    return (
        ["map_us", "copy_us", "map_fraction", "dsa_batched_us",
         "per_req_speedup"],
        rows,
    )


def fig10_validation(quick=False):
    """Emulated IOPS vs the modeled device (fio-like + BaM-like loads)."""
    rows = []
    # Closed-form reference for the modeled SSD: IOPS(outstanding) =
    # min(T_max, outstanding / L_min) — M/D/K with deterministic service.
    ssd = C.D7_PS1010
    threads = [256, 2048, 16384] if quick else [256, 1024, 4096, 16384, 32768]
    for n_out in threads:
        depth = max(1, n_out // 32)
        wl = WorkloadConfig(io_depth=depth)
        ref_iops = min(ssd.t_max_iops, n_out / (ssd.l_min_us * 1e-6))
        swarm = C.run_engine(
            C.swarmio_cfg(sq_depth=max(1024, depth)), ssd, wl, rounds=48
        )
        s_iops = float(swarm.metrics.iops())
        m = swarm.metrics
        rows.append([
            n_out, ref_iops / 1e6, s_iops / 1e6,
            abs(s_iops - ref_iops) / ref_iops * 100,
            float(m.avg_e2e_us()), float(m.p50_us()), float(m.p95_us()),
            float(m.p99_us()),
        ])
    err = sum(r[3] for r in rows) / len(rows)
    last = rows[-1]
    print(f"fig10: SwarmIO avg relative IOPS error vs modeled device: "
          f"{err:.1f}% (paper: 7.4-7.7%); latency @max load "
          f"p50={last[5]:.0f} p95={last[6]:.0f} p99={last[7]:.0f} us")
    return (
        ["outstanding", "device_miops", "swarmio_miops", "rel_err_pct",
         "avg_e2e_us", "p50_us", "p95_us", "p99_us"],
        rows,
    )


def fig11_latency_breakdown(quick=False):
    """Target vs Proc vs E2E under GPU-initiated I/O."""
    rows = []
    wl = WorkloadConfig(io_depth=512)
    for name, cfg in [
        ("nvmevirt", C.nvmevirt_cfg()),
        ("swarmio", C.swarmio_cfg()),
    ]:
        out = C.run_engine(cfg, C.D7_PS1010, wl, rounds=32)
        m = out.metrics
        rows.append([
            name, float(m.avg_target_us()), float(m.avg_proc_us()),
            float(m.avg_e2e_us()), float(m.p50_us()), float(m.p95_us()),
            float(m.p99_us()),
        ])
    base_e2e = rows[0][3]
    swarm_e2e = rows[1][3]
    print(f"fig11: E2E latency nvmevirt={base_e2e:.0f}us "
          f"swarmio={swarm_e2e:.0f}us ({base_e2e/swarm_e2e:.1f}x lower); "
          f"swarmio p50={rows[1][4]:.0f} p95={rows[1][5]:.0f} "
          f"p99={rows[1][6]:.0f} us")
    return (
        ["engine", "target_us", "proc_us", "e2e_us", "p50_us", "p95_us",
         "p99_us"],
        rows,
    )


def fig12_scalability(quick=False):
    """(a) achieved IOPS + wall-clock engine speed vs baseline;
    (b) sustained vs target. The paper's 303.9x headline is the achieved-
    IOPS ratio under GPU-initiated I/O at the 40 MIOPS target."""
    rows = []
    wl = WorkloadConfig(io_depth=256)
    base_rps, base_out = C.wallclock_engine(
        C.nvmevirt_cfg(), C.FUTURE_40M, wl, rounds=8, reps=2
    )
    base_iops = float(base_out.metrics.iops())
    rows.append(["wallclock", 0, base_rps / 1e6, 1.0, base_iops / 1e6])
    units = [4, 16] if quick else [1, 2, 4, 8, 16]
    best_rps, best_iops = 0.0, 0.0
    for u in units:
        rps, out = C.wallclock_engine(
            C.swarmio_cfg(num_units=u), C.FUTURE_40M, wl, rounds=8, reps=2
        )
        best_rps = max(best_rps, rps)
        best_iops = max(best_iops, float(out.metrics.iops()))
        rows.append(["wallclock", u, rps / 1e6, rps / base_rps,
                     float(out.metrics.iops()) / 1e6])
    # (b) sustained virtual IOPS vs configured target. The 45M point stays
    # in the quick sweep: CI's bench-smoke job asserts the emulator still
    # sustains >= 40 MIOPS virtual throughput there (scripts/
    # check_bench_floor.py).
    targets = (
        [10e6, 40e6, 45e6] if quick
        else [5e6, 10e6, 20e6, 30e6, 40e6, 45e6]
    )
    for t in targets:
        ssd = C.FUTURE_40M.replace(t_max_iops=t)
        out = C.run_engine(C.swarmio_cfg(), ssd,
                           WorkloadConfig(io_depth=1024), rounds=64)
        sustained = float(out.metrics.iops())
        rows.append(["sustained", t / 1e6, sustained / 1e6,
                     sustained / t, ""])
    print(f"fig12: achieved {best_iops/1e6:.1f} vs NVMeVirt "
          f"{base_iops/1e6:.2f} MIOPS under GPU-initiated I/O = "
          f"{best_iops/base_iops:.0f}x (paper: 303.9x); engine wall-clock "
          f"{best_rps/1e6:.2f}M req/s ({best_rps/base_rps:.1f}x baseline "
          f"impl)")
    return (
        ["kind", "units_or_target", "miops", "speedup_or_fraction",
         "virtual_miops"],
        rows,
    )


def fig13_frontend_ablation(quick=False):
    """Base / +D / +D+A / +D+C / +D+A+C frontend throughput."""
    plat = _frontend_only_platform()
    wl = WorkloadConfig(io_depth=1024)
    ssd = C.FUTURE_40M.replace(t_max_iops=100e6, n_instances=1024)
    sw = lambda **kw: C.swarmio_cfg(batched_datapath=False, **kw)
    cases = [
        ("base", C.nvmevirt_cfg(), plat),
        ("D", sw(coalesced=False, dsa_fetch=False), plat),
        ("D+A", sw(coalesced=False, dsa_fetch=True), plat),
        ("D+C", sw(coalesced=True, dsa_fetch=False), plat),
        ("D+A+C", sw(coalesced=True, dsa_fetch=True), plat),
    ]
    rows = []
    for name, cfg, p in cases:
        out = C.run_engine(cfg, ssd, wl, p, rounds=24)
        rows.append([name, float(out.metrics.iops()) / 1e6])
    by = {r[0]: r[1] for r in rows}
    print(f"fig13: base={by['base']:.2f} D={by['D']:.2f} "
          f"D+A={by['D+A']:.2f} D+C={by['D+C']:.2f} "
          f"D+A+C={by['D+A+C']:.2f} MIOPS "
          f"({by['D+A+C']/by['base']:.0f}x, paper: 537x)")
    return ["config", "frontend_miops"], rows


def fig14_timing_ablation(quick=False):
    """Aggregated vs per-request timing updates vs #service units."""
    rows = []
    units = [4, 16] if quick else [2, 4, 8, 16]
    for u in units:
        target = 10e6 * u / 4
        ssd = C.FUTURE_40M.replace(t_max_iops=target)
        wl = WorkloadConfig(io_depth=1024)
        agg = C.run_engine(C.swarmio_cfg(num_units=u), ssd, wl, rounds=32)
        per = C.run_engine(
            C.swarmio_cfg(num_units=u, mode="per_request"), ssd, wl,
            rounds=32,
        )
        rows.append([
            u, target / 1e6,
            float(agg.metrics.iops()) / 1e6,
            float(per.metrics.iops()) / 1e6,
        ])
    last = rows[-1]
    print(f"fig14: at {last[0]} units aggregated={last[2]:.1f} MIOPS vs "
          f"per-request={last[3]:.1f} MIOPS ({last[2]/last[3]:.1f}x, "
          f"paper: 3.6x)")
    return ["units", "target_miops", "aggregated_miops",
            "per_request_miops"], rows


def fig15_sensitivity(quick=False):
    """(a) #queues sweep; (b) block-size sweep."""
    rows = []
    ssd = C.FUTURE_40M
    queues = [32, 256] if quick else [32, 128, 512, 1024]
    for q in queues:
        depth = max(2048 * 32 // q, 8)
        wl = WorkloadConfig(io_depth=depth)
        out = C.run_engine(
            C.swarmio_cfg(num_sqs=q, fetch_width=32,
                          sq_depth=max(1024, depth)),
            ssd, wl, rounds=24,
        )
        rows.append(["queues", q, float(out.metrics.iops()) / 1e6, ""])
    # Block size: aggregate DSA->GPU bandwidth capped ~42 GB/s (paper).
    plat = PlatformModel(dsa_bytes_per_us=42000.0 / 16)
    sizes = [1, 4] if quick else [1, 2, 4, 8, 16]
    for nb in sizes:  # blocks of 512B per request
        wl = WorkloadConfig(io_depth=1024)
        cfg = C.swarmio_cfg()
        ssd_nb = ssd.replace(block_bytes=512 * nb)
        out = C.run_engine(cfg, ssd_nb, wl, plat, rounds=24)
        iops = float(out.metrics.iops())
        rows.append([
            "block_size", 512 * nb, iops / 1e6, iops * 512 * nb / 1e9,
        ])
    print("fig15: " + "; ".join(
        f"{r[0]}={r[1]}: {r[2]:.1f} MIOPS" for r in rows[:3]
    ))
    return ["kind", "value", "miops", "gbps"], rows


def fig16_vector_search(quick=False):
    """QPS vs SSD IOPS x batch x width (+ recall) — paper's case study."""
    from repro.apps import vector_search as vs

    rows = []
    n = 1024 if quick else 4096
    iops_list = [2.5e6, 40e6] if quick else [2.5e6, 5e6, 10e6, 20e6, 40e6]
    batches = [4, 64] if quick else [4, 16, 64, 256]
    for iops in iops_list:
        for b in batches:
            out = vs.case_study(n=n, batch=b, width=4, t_max_iops=iops)
            rows.append([
                "batch_sweep", iops / 1e6, b, 4, out["qps"], out["recall"],
            ])
    widths = [2, 8] if quick else [1, 2, 4, 8]
    for iops in ([2.5e6, 40e6] if quick else [2.5e6, 10e6, 40e6]):
        for w in widths:
            # Iterations scaled down with width for iso-recall search cost.
            iters = max(6, int(28 / max(w, 1) + 8))
            out = vs.case_study(
                n=n, batch=64, width=w, iterations=iters, t_max_iops=iops
            )
            rows.append([
                "width_sweep", iops / 1e6, 64, w, out["qps"], out["recall"],
            ])
    big = [r for r in rows if r[0] == "batch_sweep" and r[2] == max(batches)]
    if len(big) >= 2:
        print(f"fig16: batch={max(batches)} QPS {big[0][4]:.0f} @2.5M -> "
              f"{big[-1][4]:.0f} @40M IOPS "
              f"({big[-1][4]/big[0][4]:.1f}x, paper: 9.7x)")
    return ["sweep", "miops", "batch", "width", "qps", "recall"], rows


def fig17_array_scaling(quick=False):
    """Multi-SSD array emulation: M vmapped 40-MIOPS drives in one jit
    program reach the paper-title 100-MIOPS regime (aggregate virtual
    IOPS across the array)."""
    from repro.core import engine

    rows = []
    wl = WorkloadConfig(io_depth=1024)
    devices = [1, 4] if quick else [1, 2, 4, 8]
    for m_dev in devices:
        out = engine.simulate(
            C.swarmio_cfg(), C.FUTURE_40M, wl, rounds=24, num_devices=m_dev
        )
        agg = float(engine.aggregate_iops(out))
        met = out.metrics
        rows.append([
            m_dev, agg / 1e6, agg / (m_dev * C.FUTURE_40M.t_max_iops),
            float(met.p50_us()), float(met.p99_us()),
        ])
    at4 = next(r[1] for r in rows if r[0] == 4)
    print(f"fig17: {rows[-1][0]}x40M array sustains {rows[-1][1]:.0f} MIOPS "
          f"aggregate (M=4: {at4:.0f} MIOPS, "
          f"{'>=' if at4 >= 100 else '<'}100M paper-title regime)")
    return ["devices", "aggregate_miops", "fraction_of_target", "p50_us",
            "p99_us"], rows


def fig18_workload_sweep(quick=False):
    """All four workload generators through the unified engine: sustained
    IOPS + latency distribution per arrival/address pattern."""
    import numpy as np

    from repro import workloads

    cfg = C.swarmio_cfg()
    ssd = C.D7_PS1010
    depth = 256 if quick else 1024
    rate = ssd.t_max_iops * 0.8
    n_trace = 4096 if quick else 16384
    trace_t = np.cumsum(
        np.full(n_trace, 1e6 / (ssd.t_max_iops * 0.5) * 1.0)
    ).astype(np.float32)
    trace = workloads.TraceReplay.from_trace(
        trace_t,
        np.arange(n_trace) % ssd.num_blocks,
        np.zeros(n_trace),
        cfg,
    )
    # Zipf runs under lba_hash routing: with the default round-robin
    # assignment addresses never reach the timing model, so skew would be
    # invisible; address-hash channel striping is what the hot spot stresses.
    cases = [
        ("closed_loop", workloads.ClosedLoop(io_depth=depth), ssd),
        ("poisson_open", workloads.PoissonOpenLoop(io_depth=depth,
                                                   rate_iops=rate), ssd),
        ("zipf_0.9_lba_hash",
         workloads.ZipfClosedLoop(io_depth=depth, theta=0.9),
         ssd.replace(routing="lba_hash")),
        ("trace_replay", trace, ssd),
    ]
    rows = []
    rounds = 24 if quick else 64
    for name, wl, ssd_case in cases:
        out = C.run_engine(cfg, ssd_case, wl, rounds=rounds)
        m = out.metrics
        rows.append([
            name, float(m.iops()) / 1e6, float(m.avg_e2e_us()),
            float(m.p50_us()), float(m.p95_us()), float(m.p99_us()),
        ])
    print("fig18: " + "; ".join(
        f"{r[0]}: {r[1]:.2f} MIOPS p99={r[5]:.0f}us" for r in rows
    ))
    return ["workload", "miops", "avg_e2e_us", "p50_us", "p95_us",
            "p99_us"], rows


def fig19_write_mix(quick=False):
    """Read/write mix sweep through the flash backend: programs serialize
    per die and GC wakes once sustained writes drain the free pool — p99
    inflates and throughput bends toward the program ceiling
    (num_chips / program_us) as the write share grows."""
    from repro import workloads

    # A die array sized for the drive class (128 dies), benchmarked at
    # steady state — the honest regime for sustained mixed traffic. The
    # coarser poll quantum covers enough virtual time per round that the
    # closed loop cycles its slots many times (write latencies spread
    # resubmissions over hundreds of us, which the default 10us quantum
    # would crawl through).
    cfg = C.swarmio_cfg(poll_quantum_us=50.0)
    ssd = C.D7_PS1010.replace(
        num_blocks=1 << 14, num_channels=16, chips_per_channel=8
    )
    depth = 32 if quick else 64
    rounds = 48 if quick else 192
    mixes = [1.0, 0.7] if quick else [1.0, 0.9, 0.7, 0.5]
    rows = []
    for rf in mixes:
        wl = workloads.SteadyStateMixed(io_depth=depth, read_frac=rf,
                                        theta=0.9)
        out = C.run_engine(cfg, ssd, wl, rounds=rounds)
        m = out.metrics
        rows.append([
            rf, float(m.iops()) / 1e6, float(m.p50_us()),
            float(m.p99_us()), float(out.device.flash.gc_count),
        ])
    ro, mix = rows[0], rows[-1]
    print(f"fig19: p99 {ro[3]:.0f}us read-only -> {mix[3]:.0f}us at "
          f"{mix[0]:.0%} reads ({mix[3]/max(ro[3], 1e-9):.1f}x, "
          f"{mix[4]:.0f} GC invocations)")
    return ["read_frac", "miops", "p50_us", "p99_us", "gc_invocations"], rows


def fig20_steady_state(quick=False):
    """Fresh vs steady-state drive under a 70/30 Zipf mix. The fresh drive
    writes into free over-provisioned pages for the whole run; the
    preconditioned drive starts fully written, so greedy GC fires from the
    first write bursts — the fresh-drive numbers overstate sustained
    performance."""
    from repro import workloads

    # Drive sized so the run's write volume crosses the steady-state
    # drive's GC watermark while the fresh drive's much larger free pool
    # (the whole physical space) stays untouched — the contrast is the
    # figure.
    cfg = C.swarmio_cfg(poll_quantum_us=50.0)
    ssd = C.D7_PS1010.replace(
        num_blocks=1 << 15, num_channels=16, chips_per_channel=8
    )
    depth = 32 if quick else 64
    rounds = 48 if quick else 192
    rows = []
    for name, wl_cls in [
        ("fresh", workloads.MixedReadWrite),
        ("steady_state", workloads.SteadyStateMixed),
    ]:
        wl = wl_cls(io_depth=depth, read_frac=0.7, theta=0.9)
        out = C.run_engine(cfg, ssd, wl, rounds=rounds)
        m = out.metrics
        rows.append([
            name, float(m.iops()) / 1e6, float(m.p50_us()),
            float(m.p99_us()), float(out.device.flash.gc_count),
            float(out.device.flash.free_pages),
        ])
    fresh, steady = rows
    print(f"fig20: fresh {fresh[1]:.2f} MIOPS p99={fresh[3]:.0f}us vs "
          f"steady-state {steady[1]:.2f} MIOPS p99={steady[3]:.0f}us "
          f"({steady[4]:.0f} GC invocations vs {fresh[4]:.0f})")
    return ["drive", "miops", "p50_us", "p99_us", "gc_invocations",
            "free_pages"], rows


def fig21_cq_coalescing(quick=False):
    """Completion-coalescing sweep (queue-pair layer): completions per CQ
    doorbell vs delivered IOPS and tail latency. With one completion per
    doorbell the per-CQ completion poster serializes at cq_doorbell_us
    and throttles the closed loop; batching completions amortizes it back
    to the device ceiling, with the added completion wait bounded by the
    coalescing timer and the engine's poll quantum."""
    from repro.core.types import QPConfig

    wl = WorkloadConfig(io_depth=1024)
    ssd = C.FUTURE_40M
    rows = []
    ns = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]
    for n_coal in ns:
        qp = QPConfig(
            cq_coalesce_n=n_coal, cq_coalesce_us=50.0, cq_doorbell_us=1.0,
            cq_poll_us=0.3, cqe_reap_us=0.02,
        )
        cfg = C.swarmio_cfg(poll_quantum_us=25.0, qp=qp)
        out = C.run_engine(cfg, ssd, wl, rounds=32)
        m = out.metrics
        rows.append([
            n_coal, float(m.iops()) / 1e6, float(m.p50_us()),
            float(m.p99_us()),
        ])
    off = C.run_engine(
        C.swarmio_cfg(poll_quantum_us=25.0), ssd, wl, rounds=32
    )
    rows.append([0, float(off.metrics.iops()) / 1e6,
                 float(off.metrics.p50_us()), float(off.metrics.p99_us())])
    lo, hi = rows[0], rows[len(ns) - 1]
    print(f"fig21: {lo[0]} completion/doorbell {lo[1]:.1f} MIOPS "
          f"p99={lo[3]:.0f}us -> {hi[0]}/doorbell {hi[1]:.1f} MIOPS "
          f"p99={hi[3]:.0f}us (neutral QP: {rows[-1][1]:.1f} MIOPS)")
    return ["coalesce_n", "miops", "p50_us", "p99_us"], rows


def fig22_cache_hit_rate(quick=False):
    """GPU page-cache sweep under a Zipf hot spot: growing the cache
    raises the stage-0 hit rate, and delivered application IOPS amplify
    monotonically with it — hits complete at GPU-local latency and never
    post an SQE, so the device budget is spent on misses only."""
    from repro import workloads
    from repro.core.types import CacheConfig

    ssd = C.D7_PS1010
    wl = workloads.ZipfClosedLoop(io_depth=256, theta=0.9)
    sets = [0, 64, 1024] if quick else [0, 16, 64, 256, 1024, 4096]
    rounds = 24 if quick else 48
    rows = []
    for s in sets:
        cc = CacheConfig(enabled=s > 0, num_sets=max(s, 1), ways=4,
                         hit_us=0.5, chase=2)
        out = C.run_engine(C.swarmio_cfg(cache=cc), ssd, wl, rounds=rounds)
        m = out.metrics
        rows.append([
            s, 4 * s, float(m.hit_rate()), float(m.iops()) / 1e6,
            float(m.p50_us()), float(m.p99_us()),
        ])
    by_hit = sorted(rows, key=lambda r: r[2])
    monotone = all(
        a[3] <= b[3] + 1e-6 for a, b in zip(by_hit, by_hit[1:])
    )
    print(f"fig22: hit rate {rows[0][2]:.2f}->{rows[-1][2]:.2f} lifts "
          f"delivered IOPS {rows[0][3]:.2f}->{rows[-1][3]:.2f} MIOPS "
          f"({rows[-1][3]/max(rows[0][3], 1e-9):.2f}x, "
          f"monotone={monotone})")
    return ["num_sets", "capacity_blocks", "hit_rate", "miops", "p50_us",
            "p99_us"], rows


def fig23_fabric_roofline(quick=False):
    """Disaggregated remote all-flash array: aggregate MIOPS vs per-link
    bandwidth and RTT. Each of the 4x40M drives sits behind its own
    NIC/link; a read returns ~528 B (CQE + 512B payload) over the RX
    direction, so the link — not the drive — becomes the roof once
    bandwidth drops below ~frame_bytes x drive_IOPS. An unconstrained
    link recovers the local-array aggregate (>= 150 MIOPS at 4x40M)."""
    import math

    from repro.core import engine
    from repro.core.types import FabricConfig

    wl = WorkloadConfig(io_depth=1024)
    m_dev = 4
    frame = FabricConfig().cqe_bytes + C.FUTURE_40M.block_bytes  # RX bytes
    rows = []
    bws = (
        [1000.0, 8000.0, float("inf")] if quick
        else [500.0, 1000.0, 2000.0, 4000.0, 8000.0, 16000.0, 32000.0,
              float("inf")]
    )
    for bw in bws:
        fab = FabricConfig(
            remote=True,
            rtt_us=10.0 if math.isfinite(bw) else 0.0,
            tx_bytes_per_us=bw, rx_bytes_per_us=bw,
            wire_txn_us=0.2 if math.isfinite(bw) else 0.0,
            mtu_batch=16 if math.isfinite(bw) else 1,
            mtu_timeout_us=20.0 if math.isfinite(bw) else 0.0,
        )
        out = C.run_engine(
            C.swarmio_cfg(fabric=fab), C.FUTURE_40M, wl, rounds=24,
            num_devices=m_dev,
        )
        agg = float(engine.aggregate_iops(out))
        roof = m_dev * bw / frame * 1e6 if math.isfinite(bw) else float("inf")
        m = out.metrics
        rows.append([
            "bw_sweep", bw if math.isfinite(bw) else "inf", 10.0,
            agg / 1e6,
            roof / 1e6 if math.isfinite(roof) else "",
            float(m.p50_us()), float(m.p99_us()),
        ])
    rtts = [0.0, 100.0] if quick else [0.0, 5.0, 20.0, 100.0]
    for rtt in rtts:
        fab = FabricConfig(remote=True, rtt_us=rtt)
        out = C.run_engine(
            C.swarmio_cfg(fabric=fab), C.FUTURE_40M, wl, rounds=24,
            num_devices=m_dev,
        )
        m = out.metrics
        rows.append([
            "rtt_sweep", "inf", rtt,
            float(engine.aggregate_iops(out)) / 1e6, "",
            float(m.p50_us()), float(m.p99_us()),
        ])
    clamped = rows[0]
    free = next(r for r in rows if r[0] == "bw_sweep" and r[1] == "inf")
    print(f"fig23: link {clamped[1]:.0f} B/us clamps the 4x40M array to "
          f"{clamped[3]:.1f} MIOPS (link roof {clamped[4]:.1f}); "
          f"unconstrained link recovers {free[3]:.0f} MIOPS "
          f"({'>=' if free[3] >= 150 else '<'}150 target)")
    return ["sweep", "link_bytes_per_us", "rtt_us", "aggregate_miops",
            "link_roof_miops", "p50_us", "p99_us"], rows


def fig24_stripe_replication(quick=False):
    """Stripe-width x replication placement over a remote 4-drive array
    (client path, fabric-limited links). Widening the stripe engages
    more links for one batch, scaling delivered IOPS toward the W-link
    roof; replica reads take a placement-skewed batch (every block
    homed on drive 0) and spread it over R candidate links by
    least-loaded routing, recovering most of the lost parallelism."""
    import jax.numpy as jnp

    from repro.core.client import StorageClient
    from repro.core.types import EngineConfig, FabricConfig

    m_dev = 4
    ssd = C.FUTURE_40M
    fab = FabricConfig(
        remote=True, rtt_us=5.0, tx_bytes_per_us=8000.0,
        rx_bytes_per_us=2000.0, wire_txn_us=0.2, mtu_batch=8,
        mtu_timeout_us=20.0,
    )
    cfg = EngineConfig(num_units=8, fetch_width=64, fabric=fab)
    client = StorageClient(ssd, cfg)
    flash = jnp.zeros((ssd.num_blocks, 8), jnp.float32)
    n = 1024 if quick else 4096
    rows = []

    def stats(kind, value, done):
        lat = jnp.sort(done)
        makespan = float(jnp.max(done))
        rows.append([
            kind, value, n / makespan,  # delivered K-IOPS... MIOPS below
            float(jnp.mean(done)),
            float(lat[int(0.99 * (n - 1))]),
        ])

    uniform = (jnp.arange(n, dtype=jnp.int32) * 13) % ssd.num_blocks
    for w in range(1, m_dev + 1):
        state = client.init_array_state(m_dev)
        _, _, done = client.read_striped(
            state, flash, uniform, jnp.float32(0), stripe_width=w
        )
        stats("stripe_width", w, done)
    # Placement skew: every block's home drive is 0; only replication
    # can re-engage the other links.
    skewed = ((jnp.arange(n, dtype=jnp.int32) * 13) % ssd.num_blocks) \
        // m_dev * m_dev
    for r in range(1, m_dev + 1):
        state = client.init_array_state(m_dev)
        _, _, done = client.read_replicated(
            state, flash, skewed, jnp.float32(0), replicas=r
        )
        stats("replicas", r, done)
    w1, w4 = rows[0], rows[m_dev - 1]
    r1, r4 = rows[m_dev], rows[-1]
    print(f"fig24: stripe width 1->{m_dev} lifts batch throughput "
          f"{w1[2]:.2f}->{w4[2]:.2f} Mreq/s; replicas 1->{m_dev} on a "
          f"skewed batch {r1[2]:.2f}->{r4[2]:.2f} Mreq/s "
          f"(p99 {r1[4]:.0f}->{r4[4]:.0f} us)")
    return ["sweep", "value", "mreq_per_s", "mean_us", "p99_us"], rows


def fig25_switch_roofline(quick=False):
    """Shared-switch incast roofline: 4x40M remote drives whose per-drive
    links are unconstrained all converge on one switch/initiator NIC.
    Aggregate MIOPS clamps at switch_bytes_per_us / frame_bytes no matter
    how fast the drives and links are (M independently-fast links now
    contend); an unconstrained switch recovers the local-array aggregate
    (>= 150 MIOPS at 4x40M)."""
    import math

    from repro.core import engine
    from repro.core.types import FabricConfig

    wl = WorkloadConfig(io_depth=1024)
    m_dev = 4
    frame = FabricConfig().cqe_bytes + C.FUTURE_40M.block_bytes
    sws = (
        [4000.0, 16000.0, float("inf")] if quick
        else [2000.0, 4000.0, 8000.0, 16000.0, 32000.0, 64000.0,
              float("inf")]
    )
    rows = []
    for sw in sws:
        fab = FabricConfig(
            remote=True, switch_bytes_per_us=sw, switch_fanin=m_dev,
        )
        out = C.run_engine(
            C.swarmio_cfg(fabric=fab), C.FUTURE_40M, wl, rounds=24,
            num_devices=m_dev,
        )
        agg = float(engine.aggregate_iops(out))
        roof = sw / frame * 1e6 if math.isfinite(sw) else float("inf")
        m = out.metrics
        rows.append([
            sw if math.isfinite(sw) else "inf",
            agg / 1e6,
            roof / 1e6 if math.isfinite(roof) else "",
            float(m.p50_us()), float(m.p99_us()),
        ])
    clamped, free = rows[0], rows[-1]
    print(f"fig25: switch {clamped[0]:.0f} B/us clamps the 4x40M array to "
          f"{clamped[1]:.1f} MIOPS (switch roof {clamped[2]:.1f}) despite "
          f"unconstrained per-drive links; unconstrained switch recovers "
          f"{free[1]:.0f} MIOPS "
          f"({'>=' if free[1] >= 150 else '<'}150 target)")
    return ["switch_bytes_per_us", "aggregate_miops", "switch_roof_miops",
            "p50_us", "p99_us"], rows


def fig26_tenant_qos(quick=False):
    """Per-tenant QoS on the wire. (a) Two equal read tenants saturate an
    RX-bound link; sweeping the weighted-fair weights moves the achieved
    completion shares to track w0/(w0+w1) (within 10%). (b) A latency
    read tenant shares a TX-bound link with a bulk-write tenant whose
    576 B frames starve the 64 B read SQEs under FIFO; the weighted
    arbiter restores read latency while the bulk tenant keeps its
    share of the wire."""
    from repro import workloads
    from repro.core.types import FabricConfig

    cfg = C.swarmio_cfg(num_sqs=16, fetch_width=64, num_units=8)
    ssd = C.FUTURE_40M
    rows = []
    rounds = 96 if quick else 192
    sweep = (
        [(1.0, 1.0), (3.0, 1.0)] if quick
        else [(1.0, 1.0), (2.0, 1.0), (3.0, 1.0), (7.0, 1.0)]
    )
    for weights in sweep:
        fab = FabricConfig(remote=True, rx_bytes_per_us=2000.0,
                           tx_bytes_per_us=8000.0, qos_weights=weights)
        wl = workloads.MultiTenant(io_depth=64,
                                   tenant_read_frac=(1.0, 1.0))
        out = C.run_engine(cfg.replace(fabric=fab), ssd, wl, rounds=rounds)
        share = out.metrics.tenant_share()
        lat = out.metrics.tenant_avg_e2e_us()
        want = weights[0] / sum(weights)
        rows.append([
            "share_sweep", f"{weights[0]:g}:{weights[1]:g}", want,
            float(share[0]), abs(float(share[0]) - want) / want,
            float(lat[0]), float(lat[1]),
        ])
    for name, weights in (
        [("fifo", ()), ("wfq_1_1", (1.0, 1.0))] if quick
        else [("fifo", ()), ("wfq_1_1", (1.0, 1.0)),
              ("wfq_4_1", (4.0, 1.0))]
    ):
        fab = FabricConfig(remote=True, tx_bytes_per_us=400.0,
                           rx_bytes_per_us=16000.0, qos_weights=weights)
        wl = workloads.MultiTenant(io_depth=64,
                                   tenant_read_frac=(1.0, 0.0))
        # The drive-class device: the contrast is wire starvation, not
        # the flash ceiling, so the D7 class keeps the device honest.
        out = C.run_engine(cfg.replace(fabric=fab), C.D7_PS1010, wl,
                           rounds=96)
        lat = out.metrics.tenant_avg_e2e_us()
        share = out.metrics.tenant_share()
        rows.append([
            "starvation", name, "", float(share[0]), "",
            float(lat[0]), float(lat[1]),
        ])
    sw = [r for r in rows if r[0] == "share_sweep"]
    worst = max(r[4] for r in sw)
    fifo = next(r for r in rows if r[1] == "fifo")
    wfq = next(r for r in rows if r[1] == "wfq_1_1")
    print(f"fig26: achieved shares track weights within "
          f"{worst*100:.1f}% (worst case, {'<=' if worst <= 0.1 else '>'}"
          f"10% target); FIFO read latency {fifo[5]:.0f}us behind bulk "
          f"writes -> {wfq[5]:.0f}us weighted "
          f"({fifo[5]/max(wfq[5], 1e-9):.1f}x lower)")
    return ["sweep", "weights", "want_share0", "share0", "share_rel_err",
            "tenant0_e2e_us", "tenant1_e2e_us"], rows


def fig29_lock_order(quick=False):
    """Ready-time vs program-order timing lock on a *misaligned*
    two-tenant mix (PR 9). Latency read tenant + bulk write tenant on
    interleaved SQs (tenant = sq % 2) with one unit per SQ, so tenant
    units alternate through the unit loop — the placement fig26
    sidesteps by aligning tenants to contiguous unit blocks. Under the
    program-order lock every latency unit serializes behind the bulk
    unit one loop position earlier even when its batch arrived first;
    the ready-time lock admits units by post-TX batch arrival and
    restores isolation. Sweeps lock_order x {FIFO, WFQ 2:1} on a
    TX-bound wire and persists latency-tenant p99 / SLO attainment to
    BENCH_lock_order.json for the floor checker's advisory."""
    import json
    import os
    import platform as _platform

    from repro import workloads
    from repro.core.types import FabricConfig

    # One unit per SQ keeps every unit single-tenant — the lock
    # serializes whole units, so this is the finest isolation any
    # acquisition order can express (see MultiTenant docstring).
    cfg = C.swarmio_cfg(num_sqs=16, fetch_width=64, num_units=16,
                        sq_depth=128)
    wl = workloads.MultiTenant(io_depth=64, tenant_read_frac=(1.0, 0.0),
                               interleave=True)
    rounds = 48 if quick else 96
    slo_us = 500.0
    rows, points = [], []
    for arb_name, weights in [("fifo", ()), ("wfq_2_1", (2.0, 1.0))]:
        fab = FabricConfig(remote=True, tx_bytes_per_us=400.0,
                           rx_bytes_per_us=16000.0, qos_weights=weights)
        for order in ("program", "ready_time"):
            out = C.run_engine(
                cfg.replace(fabric=fab, lock_order=order),
                C.D7_PS1010, wl, rounds=rounds,
            )
            m = out.metrics
            p99 = m.tenant_p99_us()
            slo = m.slo_attainment(slo_us)
            share = m.tenant_share()
            row = [arb_name, order, float(p99[0]), float(p99[1]),
                   float(slo[0]), float(share[0])]
            rows.append(row)
            points.append({
                "arbiter": arb_name, "lock_order": order,
                "latency_p99_us": float(p99[0]),
                "bulk_p99_us": float(p99[1]),
                "latency_slo_attainment": float(slo[0]),
                "latency_share": float(share[0]),
                "slo_us": slo_us,
            })

    def _p99(arb, order):
        return next(r[2] for r in rows if r[0] == arb and r[1] == order)

    json_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_lock_order.json",
    )
    payload = {}
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            payload = {}
    payload.update({
        "schema": "lock_order/v1",
        "quick": quick,
        "host": {
            "machine": _platform.machine(),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "slo_us": slo_us,
        "fig29": points,
    })
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"  -> {json_path} [fig29]")
    wfq_gain = _p99("wfq_2_1", "program") / max(
        _p99("wfq_2_1", "ready_time"), 1e-9
    )
    print(f"fig29: misaligned latency-tenant p99 under WFQ "
          f"{_p99('wfq_2_1', 'program'):.0f}us (program lock) -> "
          f"{_p99('wfq_2_1', 'ready_time'):.0f}us (ready-time lock, "
          f"{wfq_gain:.1f}x lower); FIFO "
          f"{_p99('fifo', 'program'):.0f} -> "
          f"{_p99('fifo', 'ready_time'):.0f}us")
    return ["arbiter", "lock_order", "latency_p99_us", "bulk_p99_us",
            "latency_slo_attainment", "latency_share"], rows


ALL = [
    ("fig03_frontend", fig03_frontend_plateau),
    ("fig04_per_request_overhead", fig04_per_request_overhead),
    ("fig10_validation", fig10_validation),
    ("fig11_latency", fig11_latency_breakdown),
    ("fig12_scalability", fig12_scalability),
    ("fig13_frontend_ablation", fig13_frontend_ablation),
    ("fig14_timing_ablation", fig14_timing_ablation),
    ("fig15_sensitivity", fig15_sensitivity),
    ("fig16_vector_search", fig16_vector_search),
    ("fig17_array_scaling", fig17_array_scaling),
    ("fig18_workload_sweep", fig18_workload_sweep),
    ("fig19_write_mix", fig19_write_mix),
    ("fig20_steady_state", fig20_steady_state),
    ("fig21_cq_coalescing", fig21_cq_coalescing),
    ("fig22_cache_hit_rate", fig22_cache_hit_rate),
    ("fig23_fabric_roofline", fig23_fabric_roofline),
    ("fig24_stripe_replication", fig24_stripe_replication),
    ("fig25_switch_roofline", fig25_switch_roofline),
    ("fig26_tenant_qos", fig26_tenant_qos),
    ("fig27_kv_serving_iops", _kv_serving.fig27),
    ("fig28_kv_tier_hierarchy", _kv_serving.fig28),
    ("fig29_lock_order", fig29_lock_order),
]
