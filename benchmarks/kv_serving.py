"""LLM-serving case study: decode tokens/s on the SSD-backed KV tier.

The end-to-end story the emulator exists to tell (paper §I): with the
cold KV history in an IOPS-optimized storage tier, decode throughput is
a function of device IOPS. Both figures run the *real* tier — paged KV
cache, page-table LBA runs, write-backs, and faults through the full
``StorageClient.submit`` rings -> timing -> flash -> CQ path — in
virtual time (deterministic; no wall-clock noise).

``fig27``  decode tokens/s vs device MIOPS (2.5 -> 40 MIOPS single
           drive, then a 4 x 40M striped array): tokens/s must be
           monotone non-decreasing in device capability and saturate at
           the GPU-compute roof (``1e6 * batch / gpu_step_us``).

``fig28``  two sweeps of the serving memory hierarchy:
           * ``hot_cache`` — HBM hot window x stage-0 GPU page-cache
             size (cache off / small / large, with readahead): larger
             stage-0 caches absorb re-faulted cold pages at GPU-local
             latency;
           * ``tenant_mix`` — a background bulk-ingest write stream
             (prefill tenant) sharing a switched remote fabric with the
             latency (decode) tenant, FIFO vs weighted-fair QoS.

Results persist to ``BENCH_kv_tier.json`` at the repo root;
``scripts/check_bench_floor.py`` runs an advisory monotonicity check
over the fig27 points.

    PYTHONPATH=src python -m benchmarks.kv_serving [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import platform

import jax

from benchmarks import common as C
from repro import configs
from repro.core.types import CacheConfig, EngineConfig, FabricConfig, SSDConfig
from repro.serving import kv_tier

SCHEMA = "kv_tier/v1"
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kv_tier.json",
)

ARCH = "yi-34b"          # smoke dims; the tier scales I/O by n_layers
GPU_STEP_US = 100.0      # modeled per-token GPU compute


def _serve_shape(quick: bool):
    # start_len stays at 512 in --quick: the cold working set is what
    # makes the low-MIOPS points storage-bound (the >= 3x fig27 gain).
    return dict(batch=4, start_len=512, n_steps=4 if quick else 16)


def _tier(**kw) -> kv_tier.KVTierConfig:
    base = dict(page_tokens=16, hot_window=64, gpu_step_us=GPU_STEP_US)
    base.update(kw)
    return kv_tier.KVTierConfig(**base)


def _ssd(miops: float) -> SSDConfig:
    """A drive at ``miops`` MIOPS (instances scaled with capability,
    latency floor held fixed so the sweep isolates the IOPS axis)."""
    return SSDConfig(
        t_max_iops=miops * 1e6, l_min_us=30.0,
        n_instances=max(64, int(miops * 12.8)), num_blocks=1 << 14,
    )


def _run(tier, ssd, ecfg, quick):
    cfg = configs.get_config(ARCH, smoke=True)
    return kv_tier.decode_tokens_per_s(
        cfg, tier, ssd, ecfg, **_serve_shape(quick)
    )


def fig27_kv_serving_iops(quick: bool = False):
    """Decode tokens/s vs device MIOPS (single drive -> 4 x 40M array)."""
    ecfg = EngineConfig(num_units=8, fetch_width=64)
    sweep = [2.5, 10.0, 40.0] if quick else [2.5, 5.0, 10.0, 20.0, 40.0]
    rows = []
    points = []
    for miops in sweep:
        r = _run(_tier(), _ssd(miops), ecfg, quick)
        rows.append(["1drive", miops, r["tokens_per_s"], r["avg_step_us"],
                     r["avg_storage_us"], r["blocks_per_step"],
                     r["iops_demand"], r["data_check_max_abs"]])
        points.append({"config": "1drive", "miops": miops, **r})
    # The paper-title regime: 4 x 40-MIOPS drives, faults striped
    # round-robin over the array (160 MIOPS aggregate).
    r = _run(_tier(num_devices=4), _ssd(40.0), ecfg, quick)
    rows.append(["4x40m_striped", 160.0, r["tokens_per_s"],
                 r["avg_step_us"], r["avg_storage_us"],
                 r["blocks_per_step"], r["iops_demand"],
                 r["data_check_max_abs"]])
    points.append({"config": "4x40m_striped", "miops": 160.0, **r})

    shape = _serve_shape(quick)
    roof = 1e6 * shape["batch"] / GPU_STEP_US
    first, last = points[0]["tokens_per_s"], points[-1]["tokens_per_s"]
    print(f"fig27: {first:,.0f} -> {last:,.0f} tok/s over "
          f"{sweep[0]}->160 MIOPS ({last / first:.1f}x, GPU roof "
          f"{roof:,.0f}); data check "
          f"{max(p['data_check_max_abs'] for p in points):.1f}")
    header = ["config", "miops", "tokens_per_s", "avg_step_us",
              "avg_storage_us", "blocks_per_step", "iops_demand",
              "data_check_max_abs"]
    return header, rows, points


def fig28_kv_tier_hierarchy(quick: bool = False):
    """Hot-window x stage-0 cache size, and tenant-mix QoS sweeps."""
    rows = []
    points = []

    # Sweep 1: HBM hot window x GPU page-cache capacity. Re-faulted
    # cold pages hit the stage-0 cache at GPU-local latency, so cache
    # capacity trades directly against device IOPS demand.
    caches = [
        ("off", CacheConfig(enabled=False)),
        ("small", CacheConfig(enabled=True, num_sets=64, ways=4,
                              readahead=2)),
        ("large", CacheConfig(enabled=True, num_sets=512, ways=8,
                              readahead=2)),
    ]
    if quick:
        caches = [caches[0], caches[2]]
    hot_windows = [32, 128] if quick else [32, 64, 128]
    for hw in hot_windows:
        for cname, ccfg in caches:
            ecfg = EngineConfig(num_units=8, fetch_width=64, cache=ccfg)
            r = _run(_tier(hot_window=hw), _ssd(2.5), ecfg, quick)
            rows.append(["hot_cache", f"hw{hw}_cache_{cname}",
                         r["tokens_per_s"], r["avg_storage_us"],
                         r["blocks_per_step"], r["data_check_max_abs"]])
            points.append({"sweep": "hot_cache", "hot_window": hw,
                           "cache": cname, **r})

    # Sweep 2: tenant mix on a remote fabric — a bulk context-ingest
    # read stream (prefill tenant) congests the shared wire against
    # the decode tenant's faults; WFQ weights protect the decode
    # tenant's latency, FIFO does not. The drive itself is fast (40M)
    # so the contention is squarely on the fabric.
    fab = dict(
        remote=True, tx_bytes_per_us=1_500.0, rx_bytes_per_us=1_500.0,
        rtt_us=2.0, wire_txn_us=0.2, mtu_batch=8, mtu_timeout_us=5.0,
        switch_bytes_per_us=1_500.0, switch_fanin=1,
    )
    mixes = [
        ("idle_fifo", 0, ()),
        ("bulk_fifo", 2048, ()),
        ("bulk_wfq_4_1", 2048, (4.0, 1.0)),
    ]
    if quick:
        mixes = mixes[1:]
    for name, bulk, weights in mixes:
        ecfg = EngineConfig(
            num_units=8, fetch_width=64,
            fabric=FabricConfig(qos_weights=weights, **fab),
        )
        r = _run(_tier(bulk_blocks_per_step=bulk), _ssd(40.0), ecfg,
                 quick)
        rows.append(["tenant_mix", name, r["tokens_per_s"],
                     r["avg_storage_us"], r["blocks_per_step"],
                     r["data_check_max_abs"]])
        points.append({"sweep": "tenant_mix", "mix": name,
                       "bulk_blocks_per_step": bulk, **r})

    hc = [p for p in points if p["sweep"] == "hot_cache"]
    tm = [p for p in points if p["sweep"] == "tenant_mix"]
    mix_txt = ", ".join(
        "{}={:,.0f}".format(p["mix"], p["tokens_per_s"]) for p in tm
    )
    print(f"fig28: hot/cache sweep {min(p['tokens_per_s'] for p in hc):,.0f}"
          f" -> {max(p['tokens_per_s'] for p in hc):,.0f} tok/s; "
          f"tenant mix {mix_txt}")
    header = ["sweep", "point", "tokens_per_s", "avg_storage_us",
              "blocks_per_step", "data_check_max_abs"]
    return header, rows, points


def _persist(key: str, points: list, quick: bool) -> None:
    """Read-modify-write ``BENCH_kv_tier.json`` with one figure's points
    (each figure can run standalone via ``benchmarks/run.py``)."""
    payload = {}
    if os.path.exists(JSON_PATH):
        try:
            with open(JSON_PATH) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            payload = {}
    shape = _serve_shape(quick)
    payload.update({
        "schema": SCHEMA,
        "quick": quick,
        "host": {
            "machine": platform.machine(),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "arch": ARCH,
        "serve_shape": shape,
        "gpu_step_us": GPU_STEP_US,
        "gpu_roof_tokens_per_s": 1e6 * shape["batch"] / GPU_STEP_US,
        key: points,
    })
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"  -> {JSON_PATH} [{key}]")


def bench(quick: bool = False):
    """Run both figures, persist the JSON, return per-figure CSV data."""
    h27, r27 = fig27(quick)
    h28, r28 = fig28(quick)
    return (h27, r27), (h28, r28)


def fig27(quick: bool = False):
    """figures.ALL entry point (also refreshes the JSON's fig27 key)."""
    h, r, p = fig27_kv_serving_iops(quick)
    _persist("fig27", p, quick)
    return h, r


def fig28(quick: bool = False):
    """figures.ALL entry point (also refreshes the JSON's fig28 key)."""
    h, r, p = fig28_kv_tier_hierarchy(quick)
    _persist("fig28", p, quick)
    return h, r


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep/steps for CI smoke")
    args = ap.parse_args()
    C.jit_warmup()
    (h27, r27), (h28, r28) = bench(quick=args.quick)
    C.write_csv("fig27_kv_serving_iops", h27, r27)
    C.write_csv("fig28_kv_tier_hierarchy", h28, r28)


if __name__ == "__main__":
    main()
