"""Benchmark driver: one benchmark per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig12]

Writes CSVs to experiments/bench/ and prints one summary line per figure.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for CI")
    ap.add_argument("--only", default=None)
    ap.add_argument("--sanitize", action="store_true",
                    help="first run each config family once with "
                         "EngineConfig.sanitize=True (checkify pipeline "
                         "invariants); fails fast on the first violation")
    args = ap.parse_args()

    from benchmarks import common as C
    from benchmarks.emulator_speed import bench_figure, sanitize_pass
    from benchmarks.figures import ALL

    # One warmup invocation before anything is timed: the first jit call
    # of the process pays backend init + dispatch warm-up on top of its
    # own compile, which would otherwise land in the first figure's time.
    C.jit_warmup()

    if args.sanitize:
        t = time.perf_counter()
        sanitize_pass(quick=args.quick)
        print(f"  sanitize pass clean ({time.perf_counter()-t:.1f}s)")

    # perf_counter everywhere: the same monotonic clock benchmarks/common.py
    # times the engine with (time.time() can step under NTP adjustment).
    t0 = time.perf_counter()
    for name, fn in ALL + [("emulator_speed", bench_figure)]:
        if args.only and args.only not in name:
            continue
        t = time.perf_counter()
        try:
            header, rows = fn(quick=args.quick)
            path = C.write_csv(name, header, rows)
            print(f"  -> {path} ({time.perf_counter()-t:.1f}s)")
        except Exception as e:  # noqa: BLE001
            print(f"  !! {name} FAILED: {type(e).__name__}: {e}")
            raise
    print(f"all benchmarks done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
