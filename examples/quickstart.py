"""Quickstart: emulate a future 40-MIOPS SSD and measure what a
GPU-initiated workload sees.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import engine
from repro.core.types import EngineConfig, SSDConfig, WorkloadConfig

# 1. Describe the device you want to emulate (NVMeVirt simple timing model).
ssd = SSDConfig(
    name="future-iops-optimized",
    t_max_iops=40e6,       # sustained random-read ceiling
    l_min_us=30.0,         # latency floor
    n_instances=512,       # abstract flash channels/controllers
    num_blocks=1 << 14,
)

# 2. Configure the SwarmIO engine: 16 service units, coalesced fetching,
#    DSA-offloaded data path, aggregated timing updates.
cfg = EngineConfig(
    num_sqs=32, sq_depth=1024, fetch_width=256,  # coalesce deeply
    num_units=16, frontend="distributed", mode="aggregated",
    coalesced=True, dsa_fetch=True, batched_datapath=True,
)

# 3. A BaM-like closed-loop workload: 32 SQs x 1024 outstanding 512B reads.
wl = WorkloadConfig(io_depth=1024)

final = engine.simulate(cfg, ssd, wl, rounds=64)
m = final.metrics
print(f"device target : {ssd.t_max_iops/1e6:.1f} MIOPS, "
      f"floor {ssd.l_min_us:.0f} us")
print(f"sustained     : {float(m.iops())/1e6:.1f} MIOPS "
      f"({float(m.iops())/ssd.t_max_iops*100:.1f}% of target)")
print(f"avg E2E       : {float(m.avg_e2e_us()):.1f} us "
      f"(includes queueing at this load)")
print(f"latency dist  : p50={float(m.p50_us()):.0f} "
      f"p95={float(m.p95_us()):.0f} p99={float(m.p99_us()):.0f} us")
print(f"requests done : {int(float(m.completed))}")

# 4. Compare with the NVMeVirt baseline under the same load.
base_cfg = EngineConfig(
    num_sqs=32, sq_depth=1024, fetch_width=64,
    num_units=1, frontend="centralized", mode="per_request",
    coalesced=False, dsa_fetch=False, batched_datapath=False,
)
base = engine.simulate(base_cfg, ssd, wl, rounds=64)
print(f"NVMeVirt base : {float(base.metrics.iops())/1e6:.2f} MIOPS "
      f"-> SwarmIO speedup "
      f"{float(m.iops())/float(base.metrics.iops()):.0f}x")

# 5. Scale out: vmap the unified pipeline over a 4-drive array — one jit
#    program emulating 4x40 MIOPS, the paper-title 100-MIOPS regime.
arr = engine.simulate(cfg, ssd, wl, rounds=64, num_devices=4)
print(f"4-drive array : {float(engine.aggregate_iops(arr))/1e6:.0f} MIOPS "
      f"aggregate (p99 {float(arr.metrics.p99_us()):.0f} us)")

# 6. Swap the arrival process: open-loop Poisson at 60% of the device
#    ceiling (closed loops can't show overload latency; open loops can).
from repro import workloads

open_wl = workloads.PoissonOpenLoop(io_depth=1024, rate_iops=24e6)
po = engine.simulate(cfg, ssd, open_wl, rounds=64)
pm = po.metrics
print(f"open-loop 24M : sustained {float(pm.iops())/1e6:.1f} MIOPS, "
      f"p99 {float(pm.p99_us()):.0f} us")

# 7. Turn on the flash-level backend's hard cases: a 70/30 read/write mix
#    on a steady-state (fully written) drive. Write programs serialize per
#    chip and greedy GC steals die time once the free-page pool drains —
#    watch the tail inflate relative to the read-only runs above.
mixed = workloads.SteadyStateMixed(io_depth=1024, read_frac=0.7, theta=0.9)
mx = engine.simulate(cfg, ssd, mixed, rounds=64)
mm = mx.metrics
print(f"70/30 steady  : {float(mm.iops())/1e6:.2f} MIOPS, "
      f"p99 {float(mm.p99_us()):.0f} us, "
      f"{float(mx.device.flash.gc_count):.0f} GC invocations")

# 8. Cold mapping state: a 50% cached-mapping-table hit rate charges a
#    translation-page read on every miss (the KV-SSD random-read story).
cold = engine.simulate(
    cfg, ssd.replace(mapping_hit_rate=0.5), wl, rounds=64
)
print(f"CMT 50% hits  : avg E2E {float(cold.metrics.avg_e2e_us()):.0f} us "
      f"vs {float(m.avg_e2e_us()):.0f} us all-hit")

# 9. The queue-pair completion path and the GPU page cache. By default
#    both are neutral: completions post to CQ rings and reap with zero
#    added time. Turning the knobs on shows the two tradeoffs:
#    (a) completion coalescing — with a per-doorbell cost, batching 16
#    completions per CQ doorbell recovers IOPS an uncoalesced stream
#    loses to doorbell serialization (fig21);
#    (b) a Zipf-hot workload in front of a GPU-side page cache — hits
#    complete at GPU-local latency and never post an SQE, so delivered
#    IOPS amplify with the hit rate (fig22).
from repro.core.types import CacheConfig, QPConfig

bell = QPConfig(cq_coalesce_n=1, cq_coalesce_us=50.0, cq_doorbell_us=1.0)
coal = bell.replace(cq_coalesce_n=16)
slow_cq = engine.simulate(cfg.replace(qp=bell), ssd, wl, rounds=64)
fast_cq = engine.simulate(cfg.replace(qp=coal), ssd, wl, rounds=64)
print(f"CQ coalescing : 1/doorbell {float(slow_cq.metrics.iops())/1e6:.1f} "
      f"MIOPS -> 16/doorbell {float(fast_cq.metrics.iops())/1e6:.1f} MIOPS")

cached_cfg = cfg.replace(
    cache=CacheConfig(enabled=True, num_sets=1024, ways=4, hit_us=0.5)
)
zipf = workloads.ZipfClosedLoop(io_depth=1024, theta=0.9)
uncached = engine.simulate(cfg, ssd, zipf, rounds=64)
cached = engine.simulate(cached_cfg, ssd, zipf, rounds=64)
cm = cached.metrics
print(f"page cache    : Zipf {float(uncached.metrics.iops())/1e6:.1f} MIOPS "
      f"-> {float(cm.iops())/1e6:.1f} MIOPS at "
      f"{float(cm.hit_rate())*100:.0f}% hit rate")

# 10. Disaggregate: put every drive of the 4-drive array behind its own
#     NIC/link (remote all-flash array). Reads return ~528 B per request
#     over the RX direction, so at 40M IOPS/drive the *wire* becomes the
#     roof long before the flash does: a 2 GB/s-class link clamps each
#     drive near rx_bytes_per_us/528 IOPS, while an unconstrained link
#     (the `remote=True` default) reproduces the local array bit-exactly.
#     Sweeps: benchmarks fig23 (bandwidth/RTT roofline) and fig24
#     (stripe-width x replication via StorageClient.read_striped /
#     read_replicated over the per-link load cursors).
from repro.core.types import FabricConfig

link = FabricConfig(
    remote=True, rtt_us=10.0,           # network round trip
    tx_bytes_per_us=8000.0,             # SQEs + write payloads ->
    rx_bytes_per_us=2000.0,             # <- CQEs + read payloads (binding)
    wire_txn_us=0.2, mtu_batch=8, mtu_timeout_us=20.0,  # NIC doorbells
)
remote = engine.simulate(cfg.replace(fabric=link), ssd, wl, rounds=64,
                         num_devices=4)
print(f"remote array  : {float(engine.aggregate_iops(remote))/1e6:.0f} MIOPS "
      f"aggregate behind 4x2 GB/s links "
      f"(local array above: {float(engine.aggregate_iops(arr))/1e6:.0f}; "
      f"p99 {float(remote.metrics.p99_us()):.0f} us)")

# 11. Share the fabric: (a) all four drives' return streams converge on
#     one switch/initiator NIC (incast) — even with unconstrained
#     per-drive links the array clamps at switch_bytes_per_us / ~528 B
#     (fig25); (b) two tenants on one remote drive — a latency
#     read tenant and a bulk-write tenant whose 576 B frames starve the
#     64 B read SQEs on the TX wire under FIFO — get weighted-fair
#     arbitration from qos_weights: backlogged classes split every
#     shared cursor in weight proportion (fig26). MultiTenant
#     partitions the SQs into contiguous per-tenant blocks.
incast = FabricConfig(remote=True, switch_bytes_per_us=8000.0,
                      switch_fanin=4)
sw = engine.simulate(cfg.replace(fabric=incast), ssd, wl, rounds=64,
                     num_devices=4)
print(f"shared switch : {float(engine.aggregate_iops(sw))/1e6:.1f} MIOPS "
      f"aggregate at an 8 GB/s switch "
      f"(roof {8000.0 / (16 + 512):.1f} MIOPS, links unconstrained)")

two_tenants = workloads.MultiTenant(io_depth=64,
                                    tenant_read_frac=(1.0, 0.0))
qos_cfg = cfg.replace(num_sqs=16, fetch_width=64, num_units=8)
d7 = SSDConfig()  # the D7-class drive: the wire binds, not the flash
for label, weights in [("fifo", ()), ("wfq 4:1", (4.0, 1.0))]:
    fab = FabricConfig(remote=True, tx_bytes_per_us=400.0,
                       rx_bytes_per_us=16000.0, qos_weights=weights)
    out = engine.simulate(qos_cfg.replace(fabric=fab), d7, two_tenants,
                          rounds=96)
    lat = out.metrics.tenant_avg_e2e_us()
    shares = [round(s, 2) for s in out.metrics.tenant_share().tolist()]
    print(f"2-tenant {label:7s}: reads {float(lat[0]):5.0f} us, bulk "
          f"writes {float(lat[1]):5.0f} us (shares {shares})")

# 12. Wall-clock speed is its own axis: the numbers above are *virtual*
#     throughput (emulated time), while how fast the engine retires
#     emulated requests per *real* second is what
#     `benchmarks/emulator_speed.py` measures (full matrix ->
#     BENCH_emulator_speed.json). EngineConfig gates the fast path:
#     use_sort_plan (default on) computes each epoch's segment
#     order/heads/rank once and reuses it across the unit, CQ, and
#     fabric sorts; use_compaction (default on) adds the sort-free
#     epoch-compacted forms (dense round-robin timing layout,
#     counting-sorted flash/lanes, block CQ ranks, fused ring
#     scatters); use_pallas_segscan (default None = auto) routes the
#     queueing recurrence through the Pallas segmented-scan kernel
#     whenever types.integer_timestamps proves it bit-exact for this
#     platform. All are bit-exact in virtual time
#     (tests/test_emulator_speed.py). donate=True lets XLA reuse the
#     state buffers in place — donated inputs must not alias, so
#     deep-copy fresh states with engine.unalias before the first call.
from repro.core.types import PlatformModel

fast_cfg = cfg.replace(use_compaction=True)  # the default, shown explicit
runner = engine.make_runner(fast_cfg, ssd, wl, PlatformModel(), rounds=8,
                            donate=True)
st = engine.unalias(engine.init_state(fast_cfg, ssd, wl))
st = jax.block_until_ready(runner(st))      # untimed: compile + warmup
t0 = time.perf_counter()
st = jax.block_until_ready(runner(st))      # steady-state round, timed
dt = time.perf_counter() - t0
done = float(st.metrics.completed)
print(f"wall-clock    : {done / dt:,.0f} emulated req/wall-sec "
      f"({done:.0f} reqs in {dt*1e3:.0f} ms; virtual "
      f"{float(st.metrics.iops())/1e6:.1f} MIOPS)")

# 13. LLM serving on the emulated array: the SSD-backed paged-KV tier
#     (src/repro/serving/) keeps each sequence's hot attention window in
#     the GPU pool and pages everything colder to the drive. Every
#     decode step faults the cold pages back in as page-table-driven
#     LBA-run reads through the same SQ -> timing -> flash -> CQ path
#     as above, demoted hot-window pages are written back through it,
#     and the bytes each fault gathers are checked bit-exactly against
#     the live pool (data_check_max_abs must be 0.0). Tokens/s is
#     min(GPU roof, storage-bound rate); striping over num_devices
#     drives lifts the storage bound (fig27/fig28,
#     benchmarks/kv_serving.py -> BENCH_kv_tier.json).
import dataclasses

from repro import configs
from repro.serving import kv_tier

model = configs.get_config("yi-34b", smoke=True)
tier = kv_tier.KVTierConfig(page_tokens=16, hot_window=64,
                            gpu_step_us=100.0)
serve_ecfg = EngineConfig(num_units=8, fetch_width=64)
for label, t, dev in [
    ("1x 2.5M drive", tier, SSDConfig(t_max_iops=2.5e6, l_min_us=30.0,
                                      n_instances=64)),
    ("4x 40M striped", dataclasses.replace(tier, num_devices=4),
     SSDConfig(t_max_iops=40e6, l_min_us=30.0, n_instances=512)),
]:
    r = kv_tier.decode_tokens_per_s(model, t, dev, serve_ecfg, batch=4,
                                    start_len=256, n_steps=4)
    print(f"kv tier {label:14s}: {r['tokens_per_s']:8,.0f} tok/s "
          f"(step {r['avg_step_us']:.0f} us, "
          f"{r['blocks_per_step']:.0f} blk/step, "
          f"data check {r['data_check_max_abs']:.1f})")

# 14. Misaligned multi-tenant isolation: the ready-time timing lock.
#     A latency read tenant and a bulk write tenant on *interleaved*
#     SQs (tenant = sq % 2, one unit per SQ) — the placement where the
#     default program-order lock chains every latency unit behind the
#     bulk unit one loop position earlier, even with weighted-fair wire
#     QoS. lock_order="ready_time" admits units by post-fabric-TX batch
#     arrival instead and restores isolation (fig29,
#     BENCH_lock_order.json).
from repro.core.types import FabricConfig
from repro.workloads import MultiTenant

mt_wl = MultiTenant(io_depth=64, tenant_read_frac=(1.0, 0.0),
                    interleave=True)
mt_ssd = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64)
for order in ("program", "ready_time"):
    mt_cfg = EngineConfig(
        num_sqs=16, num_units=16, sq_depth=128, fetch_width=64,
        fabric=FabricConfig(remote=True, tx_bytes_per_us=400.0,
                            rx_bytes_per_us=16000.0,
                            qos_weights=(2.0, 1.0)),
        lock_order=order,
    )
    mm = engine.simulate(mt_cfg, mt_ssd, mt_wl, rounds=32).metrics
    p99 = mm.tenant_p99_us()
    slo = mm.slo_attainment(500.0)
    print(f"lock {order:10s}: latency-tenant p99 {float(p99[0]):7.0f} us "
          f"(SLO<=500us attained {float(slo[0])*100:5.1f}%), "
          f"bulk p99 {float(p99[1]):7.0f} us")

# 15. Trust but checkify: sanitize=True threads jax.experimental.checkify
#     assertions through the whole pipeline (ring indices in bounds,
#     completion times monotone and non-negative, valid-mask
#     conservation across the compaction/admission permutations, flash
#     free-page and fabric cursor invariants). The checks only observe —
#     the sanitized run's final state is bitwise identical to the
#     default run's (tests/test_sanitize.py) — but the program is
#     slower, so it's off by default; benchmarks/run.py --sanitize and
#     scripts/profile_engine.py --sanitize run it as a certification
#     pass before timing anything. A violated invariant raises
#     checkify.JaxRuntimeError with the failed check's message.
san_runner = engine.make_runner(fast_cfg, ssd, wl, PlatformModel(),
                                rounds=8, sanitize=True)
san = jax.block_until_ready(
    san_runner(engine.init_state(fast_cfg, ssd, wl))
)
print(f"sanitized run : checkify-clean, "
      f"{float(san.metrics.completed):.0f} reqs retired "
      f"(bit-exact with the unsanitized pipeline)")
