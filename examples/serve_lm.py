"""Serving example: generate from a zoo arch (smoke config) with the
SSD-backed cold KV tier, showing tokens/s as a function of device IOPS.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro import configs
from repro.core.types import SSDConfig
from repro.models import transformer
from repro.serving import loop as serve_loop
from repro.serving.kv_tier import KVTierConfig

cfg = configs.get_config("gemma2-27b", smoke=True)
params = transformer.init_model(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (16, 128), 0, cfg.vocab)
scfg = serve_loop.ServeConfig(
    batch=16, prompt_len=128, gen_tokens=8,
    tier=KVTierConfig(hot_window=16, page_tokens=8, gpu_step_us=120.0),
)

print(f"arch={cfg.name} (smoke), batch=16, prompt=128, gen=8")
for miops in (2.5, 10.0, 40.0):
    ssd = SSDConfig(t_max_iops=miops * 1e6,
                    n_instances=max(64, int(miops * 25)),
                    num_blocks=1 << 14)
    out = serve_loop.serve_with_kv_tier(cfg, params, tokens, scfg, ssd)
    print(f"  SSD {miops:5.1f} MIOPS -> {out['tokens_per_s']:8.1f} tok/s "
          f"(storage {out['avg_storage_us']:6.1f} us/step, "
          f"demand {out['iops_demand']/1e6:.2f} MIOPS)")
print("same generated tokens regardless of device speed (functional path "
      "is device-independent)")
