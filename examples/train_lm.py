"""End-to-end training example: a ~100M-param LM trained with the full
production loop (prefetching data pipeline, AdamW, checkpoints, restart).

    PYTHONPATH=src python examples/train_lm.py            # ~100M, few steps
    PYTHONPATH=src python examples/train_lm.py --steps 300  # longer run
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-fast variant
"""
import argparse

from repro.models.config import ATTN, ModelConfig
from repro.train import loop as train_loop


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=10, d_head=64,
        d_ff=2560, vocab=32000,
        pattern=(ATTN,),
        norm="rmsnorm", mlp_act="silu", mlp_gated=True,
        rope="rope", tie_embeddings=True,
        dtype="float32", loss_chunk=128, attn_chunk=256, remat=False,
    )


def model_tiny() -> ModelConfig:
    return model_100m().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=512, vocab=1024,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.0f}M params")
    tcfg = train_loop.TrainConfig(
        batch=args.batch, seq=args.seq, steps=args.steps,
        ckpt_every=max(args.steps // 2, 1), ckpt_dir=args.ckpt,
    )
    res = train_loop.train(cfg, tcfg, resume=False, log=print)
    print(f"trained {res.step} steps in {res.wall_s:.1f}s; "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f} "
          f"(min {min(res.losses):.4f})")
    # Synthetic tokens are uniform-random: the achievable floor is ln(vocab)
    # and the curve is noisy around it once reached — assert the model
    # moved toward the floor, not strict monotonicity.
    assert min(res.losses) < res.losses[0], "loss should move toward floor"


if __name__ == "__main__":
    main()
