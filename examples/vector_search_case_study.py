"""Paper §VII case study (reduced): on-disk CAGRA-style vector search,
sweeping emulated SSD IOPS — reproduces the batch-size sensitivity and the
IOPS-dependent optimal search width.

    PYTHONPATH=src python examples/vector_search_case_study.py
"""
from repro.apps import vector_search as vs

print("== QPS vs IOPS x batch (width=4) ==")
for miops in (2.5, 10.0, 40.0):
    for batch in (4, 64):
        out = vs.case_study(n=1024, batch=batch, width=4,
                            t_max_iops=miops * 1e6)
        print(f"  {miops:5.1f} MIOPS batch={batch:3d}: "
              f"QPS={out['qps']:8.0f} recall@10={out['recall']:.3f}")

print("== optimal width shifts with IOPS (batch=64, iso-iteration) ==")
for miops in (2.5, 40.0):
    best = None
    for w in (1, 2, 4, 8):
        iters = max(6, int(28 / w + 8))
        out = vs.case_study(n=1024, batch=64, width=w, iterations=iters,
                            t_max_iops=miops * 1e6)
        tag = f"W={w}: QPS={out['qps']:7.0f} recall={out['recall']:.2f}"
        if best is None or out["qps"] > best[0]:
            best = (out["qps"], w)
        print(f"  {miops:5.1f} MIOPS {tag}")
    print(f"  -> optimal width at {miops} MIOPS: W={best[1]}")
