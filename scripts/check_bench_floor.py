"""CI bench-smoke gate: the emulator must still hit its headline number.

Reads the fig12 scalability CSV produced by ``benchmarks/run.py`` and
fails (exit 1) unless some fig12 point sustains at least ``--min-miops``
of *virtual* throughput — the 40-MIOPS-class device the paper's
IOPS-optimized targets are calibrated against. Wall-clock speed varies
with the CI machine; virtual throughput must not, so a regression here
means the device model or the engine got slower in emulated time, not
that the runner was busy.

Alongside the hard virtual floor, an *advisory* wall-clock floor is
logged from ``BENCH_emulator_speed.json`` (written by
``benchmarks/emulator_speed.py``): if the best optimized-variant
emulated-requests-per-wall-second falls below
``--advisory-req-per-wall-s`` a WARN line is printed, but the exit code
never changes — CI runners are too heterogeneous for a hard wall-clock
gate, yet a sudden order-of-magnitude drop should be visible in the log.

A second advisory reads ``BENCH_kv_tier.json`` (written by
``benchmarks/kv_serving.py``): fig27's decode tokens/s must be
monotone non-decreasing in device MIOPS (virtual time — deterministic,
so a violation means the tier or the device model regressed, yet it
stays advisory because the smoke sweep is a reduced shape).

A third advisory reads ``BENCH_lock_order.json`` (written by fig29 in
``benchmarks/figures.py``): on the misaligned two-tenant WFQ mix the
ready-time timing lock must not leave the latency tenant's p99 above
the program-order lock's (that isolation is the refactor's whole
point). Virtual time again, but advisory: the smoke sweep is short
and the margin on a reduced round count is config-sensitive.

    PYTHONPATH=src python scripts/check_bench_floor.py --min-miops 40
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
from pathlib import Path


def best_virtual_miops(csv_path: Path) -> float:
    best = 0.0
    with csv_path.open() as f:
        for row in csv.DictReader(f):
            # Sustained rows carry virtual MIOPS in `miops`; wallclock
            # rows carry it in `virtual_miops`.
            cell = (
                row["miops"]
                if row.get("kind") == "sustained"
                else row.get("virtual_miops", "")
            )
            try:
                best = max(best, float(cell))
            except ValueError:
                continue
    return best


def advisory_wallclock(json_path: Path, floor: float) -> None:
    """Log (never fail) the wall-clock floor from the speed benchmark.

    Also reports each config's optimized-vs-seed (and +pallas-vs-seed)
    speedup ratio so a collapsing optimization shows up in the CI log
    even while absolute rates drift with the runner hardware.
    """
    if not json_path.exists():
        print(f"note: {json_path} missing — wall-clock advisory skipped")
        return
    data = json.loads(json_path.read_text())
    best = 0.0
    best_cfg = "?"
    for cfg in data.get("configs", []):
        rate = (
            cfg.get("variants", {})
            .get("optimized", {})
            .get("req_per_wall_s", 0.0)
        )
        if rate > best:
            best, best_cfg = rate, cfg["name"]
        opt = cfg.get("speedup_optimized_vs_seed")
        pal = cfg.get("speedup_optimized_pallas_vs_seed")
        print(
            f"note (advisory): {cfg['name']} optimized-vs-seed "
            f"{f'{opt:.2f}x' if opt else 'n/a'}, +pallas "
            f"{f'{pal:.2f}x' if pal else 'n/a'}"
        )
    verdict = "OK" if best >= floor else "WARN"
    print(
        f"{verdict} (advisory): best optimized wall-clock rate "
        f"{best:,.0f} emulated req/wall-s ({best_cfg}; advisory floor "
        f"{floor:,.0f} — never fails the job)"
    )


def advisory_kv_tier(json_path: Path) -> None:
    """Log (never fail) fig27 tokens/s monotonicity in device MIOPS."""
    if not json_path.exists():
        print(f"note: {json_path} missing — kv-tier advisory skipped")
        return
    points = json.loads(json_path.read_text()).get("fig27", [])
    points = sorted(points, key=lambda p: p["miops"])
    if len(points) < 2:
        print("note: fewer than 2 fig27 points — kv-tier advisory skipped")
        return
    rates = [p["tokens_per_s"] for p in points]
    bad = [
        (points[i]["miops"], points[i + 1]["miops"])
        for i in range(len(rates) - 1)
        if rates[i + 1] < rates[i]
    ]
    gain = rates[-1] / rates[0] if rates[0] else float("inf")
    if bad:
        print(
            f"WARN (advisory): fig27 decode tokens/s NOT monotone in "
            f"device MIOPS — decreases at {bad} (never fails the job)"
        )
    else:
        print(
            f"OK (advisory): fig27 decode tokens/s monotone over "
            f"{points[0]['miops']}->{points[-1]['miops']} MIOPS "
            f"({gain:.1f}x gain)"
        )


def advisory_lock_order(json_path: Path) -> None:
    """Log (never fail) the fig29 ready-time-lock isolation check."""
    if not json_path.exists():
        print(f"note: {json_path} missing — lock-order advisory skipped")
        return
    points = json.loads(json_path.read_text()).get("fig29", [])

    def p99(arb, order):
        return next(
            (
                p["latency_p99_us"]
                for p in points
                if p["arbiter"] == arb and p["lock_order"] == order
            ),
            None,
        )

    prog, ready = p99("wfq_2_1", "program"), p99("wfq_2_1", "ready_time")
    if prog is None or ready is None:
        print("note: fig29 WFQ points missing — lock-order advisory skipped")
        return
    if ready <= prog:
        gain = prog / max(ready, 1e-9)
        print(
            f"OK (advisory): fig29 misaligned WFQ latency-tenant p99 "
            f"{prog:.0f}us (program lock) -> {ready:.0f}us (ready-time, "
            f"{gain:.1f}x lower)"
        )
    else:
        print(
            f"WARN (advisory): fig29 ready-time lock RAISED the "
            f"misaligned WFQ latency-tenant p99: {prog:.0f}us (program) "
            f"-> {ready:.0f}us (never fails the job)"
        )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-miops", type=float, default=40.0)
    ap.add_argument(
        "--csv",
        default="experiments/bench/fig12_scalability.csv",
        help="fig12 CSV written by benchmarks/run.py",
    )
    ap.add_argument(
        "--wallclock-json",
        default="BENCH_emulator_speed.json",
        help="emulator-speed JSON written by benchmarks/emulator_speed.py",
    )
    ap.add_argument(
        "--advisory-req-per-wall-s", type=float, default=10_000.0,
        help="advisory (non-failing) wall-clock floor, emulated req/s",
    )
    ap.add_argument(
        "--kv-tier-json",
        default="BENCH_kv_tier.json",
        help="kv-tier serving JSON written by benchmarks/kv_serving.py",
    )
    ap.add_argument(
        "--lock-order-json",
        default="BENCH_lock_order.json",
        help="lock-order JSON written by fig29 (benchmarks/figures.py)",
    )
    args = ap.parse_args()

    advisory_wallclock(
        Path(args.wallclock_json), args.advisory_req_per_wall_s
    )
    advisory_kv_tier(Path(args.kv_tier_json))
    advisory_lock_order(Path(args.lock_order_json))
    path = Path(args.csv)
    if not path.exists():
        print(f"FAIL: {path} missing — did the benchmark run?")
        return 1
    best = best_virtual_miops(path)
    verdict = "OK" if best >= args.min_miops else "FAIL"
    print(
        f"{verdict}: best fig12 virtual throughput {best:.1f} MIOPS "
        f"(floor {args.min_miops:.0f})"
    )
    return 0 if best >= args.min_miops else 1


if __name__ == "__main__":
    sys.exit(main())
