"""CI bench-smoke gate: the emulator must still hit its headline number.

Reads the fig12 scalability CSV produced by ``benchmarks/run.py`` and
fails (exit 1) unless some fig12 point sustains at least ``--min-miops``
of *virtual* throughput — the 40-MIOPS-class device the paper's
IOPS-optimized targets are calibrated against. Wall-clock speed varies
with the CI machine; virtual throughput must not, so a regression here
means the device model or the engine got slower in emulated time, not
that the runner was busy.

    PYTHONPATH=src python scripts/check_bench_floor.py --min-miops 40
"""
from __future__ import annotations

import argparse
import csv
import sys
from pathlib import Path


def best_virtual_miops(csv_path: Path) -> float:
    best = 0.0
    with csv_path.open() as f:
        for row in csv.DictReader(f):
            # Sustained rows carry virtual MIOPS in `miops`; wallclock
            # rows carry it in `virtual_miops`.
            cell = (
                row["miops"]
                if row.get("kind") == "sustained"
                else row.get("virtual_miops", "")
            )
            try:
                best = max(best, float(cell))
            except ValueError:
                continue
    return best


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-miops", type=float, default=40.0)
    ap.add_argument(
        "--csv",
        default="experiments/bench/fig12_scalability.csv",
        help="fig12 CSV written by benchmarks/run.py",
    )
    args = ap.parse_args()

    path = Path(args.csv)
    if not path.exists():
        print(f"FAIL: {path} missing — did the benchmark run?")
        return 1
    best = best_virtual_miops(path)
    verdict = "OK" if best >= args.min_miops else "FAIL"
    print(
        f"{verdict}: best fig12 virtual throughput {best:.1f} MIOPS "
        f"(floor {args.min_miops:.0f})"
    )
    return 0 if best >= args.min_miops else 1


if __name__ == "__main__":
    sys.exit(main())
