"""Local dry-run of .github/workflows/ci.yml (act-equivalent).

Parses the workflow and executes every ``run:`` step of every job in
order, with the workflow's ``env:`` applied — so new steps register here
automatically (the bench-smoke job currently runs the fig12 floor check
plus the fig21 CQ-coalescing, fig22 cache-hit-rate, fig23 fabric-
roofline, fig24 stripe/replication, fig25 switch-roofline, fig26
tenant-QoS, and fig27/fig28 kv-serving-tier quick benchmarks — the
latter also writes ``BENCH_kv_tier.json`` for the floor script's
tokens/s-monotonicity advisory).
Steps whose executable is not installed locally (e.g. ``ruff`` on a
runtime-only box) are reported as SKIPPED rather than failed — CI still
runs them; this script tells you everything that *can* be validated
locally passes.

    python scripts/ci_dryrun.py [job ...]
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import time
from pathlib import Path

import yaml

REPO = Path(__file__).resolve().parent.parent
WORKFLOW = REPO / ".github/workflows/ci.yml"


def main() -> int:
    wf = yaml.safe_load(WORKFLOW.read_text())
    only = set(sys.argv[1:])
    env = dict(os.environ)
    env.update({k: str(v) for k, v in (wf.get("env") or {}).items()})

    # One warmup invocation before any step is timed: pays interpreter
    # start + jax import + first-jit dispatch once, so the per-step
    # PASS/FAIL wall-clock below reflects the step's own work rather
    # than mixing in the process-wide jit cold start.
    print("WARM  jax import + first jit (untimed)")
    subprocess.run(
        [sys.executable, "-c",
         "import jax, jax.numpy as jnp; "
         "jax.block_until_ready(jax.jit(lambda x: x + 1)(jnp.ones((8,))))"],
        env=env, cwd=REPO, check=False,
    )

    failed, skipped, ran = [], [], []
    for job_name, job in wf["jobs"].items():
        if only and job_name not in only:
            continue
        for step in job["steps"]:
            cmd = step.get("run")
            if cmd is None:
                continue  # uses: actions are CI-side only
            label = f"{job_name} / {step.get('name', cmd.split()[0])}"
            tool = cmd.strip().split()[0]
            if shutil.which(tool) is None:
                print(f"SKIP  {label} ({tool} not installed here)")
                skipped.append(label)
                continue
            if tool == "pip":
                print(f"SKIP  {label} (no package installs in dry-run)")
                skipped.append(label)
                continue
            print(f"RUN   {label}")
            t0 = time.perf_counter()  # monotonic, matches benchmarks/common
            proc = subprocess.run(cmd, shell=True, env=env, cwd=REPO)
            dt = time.perf_counter() - t0
            if proc.returncode != 0:
                print(f"FAIL  {label} (exit {proc.returncode}, {dt:.0f}s)")
                failed.append(label)
            else:
                print(f"PASS  {label} ({dt:.0f}s)")
                ran.append(label)

    print(
        f"\nci dry-run: {len(ran)} passed, {len(skipped)} skipped, "
        f"{len(failed)} failed"
    )
    for f in failed:
        print(f"  FAILED: {f}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
