"""Generate the EXPERIMENTS.md §Roofline table from experiments/dryrun/."""

import glob
import json

rows = []
for f in sorted(glob.glob("experiments/dryrun/*__single.json")):
    r = json.load(open(f))
    if r["status"] == "skipped":
        arch, shape, _ = r["cell"].split("__")
        rows.append((arch, shape, None))
        continue
    if r["status"] != "ok":
        continue
    rows.append((r["arch"], r["shape"], r))

print(
    "| arch | shape | compute (s) | memory (s) | collective (s) | "
    "bottleneck | roofline frac | useful ratio | HBM peak (GB) |"
)
print("|---|---|---|---|---|---|---|---|---|")
for arch, shape, r in rows:
    if r is None:
        print(
            f"| {arch} | {shape} | — | — | — | skipped (full-attention, "
            f"per assignment) | — | — | — |"
        )
        continue
    u = r.get("useful_compute_ratio")
    useful = f"{u:.2f}" if u is not None else "—"
    print(
        f"| {arch} | {shape} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
        f"| {r['collective_s']:.3e} | {r['bottleneck']} "
        f"| {r['roofline_fraction']:.3f} | {useful} "
        f"| {r['hbm_peak_bytes'] / 1e9:.1f} |"
    )

print()
print("multi-pod (2x16x16) status:")
ok = err = skip = 0
for f in sorted(glob.glob("experiments/dryrun/*__multi.json")):
    r = json.load(open(f))
    ok += r["status"] == "ok"
    err += r["status"] == "error"
    skip += r["status"] == "skipped"
print(f"  ok={ok} err={err} skipped={skip}")
