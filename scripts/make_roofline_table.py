"""Generate the EXPERIMENTS.md §Roofline table from experiments/dryrun/,
plus the emulator-speed table from BENCH_emulator_speed.json (virtual
and wall-clock throughput side by side)."""

import glob
import json
import os

rows = []
for f in sorted(glob.glob("experiments/dryrun/*__single.json")):
    r = json.load(open(f))
    if r["status"] == "skipped":
        arch, shape, _ = r["cell"].split("__")
        rows.append((arch, shape, None))
        continue
    if r["status"] != "ok":
        continue
    rows.append((r["arch"], r["shape"], r))

print(
    "| arch | shape | compute (s) | memory (s) | collective (s) | "
    "bottleneck | roofline frac | useful ratio | HBM peak (GB) |"
)
print("|---|---|---|---|---|---|---|---|---|")
for arch, shape, r in rows:
    if r is None:
        print(
            f"| {arch} | {shape} | — | — | — | skipped (full-attention, "
            f"per assignment) | — | — | — |"
        )
        continue
    u = r.get("useful_compute_ratio")
    useful = f"{u:.2f}" if u is not None else "—"
    print(
        f"| {arch} | {shape} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
        f"| {r['collective_s']:.3e} | {r['bottleneck']} "
        f"| {r['roofline_fraction']:.3f} | {useful} "
        f"| {r['hbm_peak_bytes'] / 1e9:.1f} |"
    )

print()
print("multi-pod (2x16x16) status:")
ok = err = skip = 0
for f in sorted(glob.glob("experiments/dryrun/*__multi.json")):
    r = json.load(open(f))
    ok += r["status"] == "ok"
    err += r["status"] == "error"
    skip += r["status"] == "skipped"
print(f"  ok={ok} err={err} skipped={skip}")

# --- emulator speed: virtual vs wall-clock throughput side by side -------
SPEED_JSON = "BENCH_emulator_speed.json"
if os.path.exists(SPEED_JSON):
    data = json.load(open(SPEED_JSON))
    print()
    print(
        f"emulator speed ({SPEED_JSON}, backend="
        f"{data.get('host', {}).get('backend', '?')}"
        f"{', quick' if data.get('quick') else ''}):"
    )
    print(
        "| config | variant | virtual MIOPS | emulated req/wall-sec | "
        "speedup vs seed |"
    )
    print("|---|---|---|---|---|")
    for cfg in data.get("configs", []):
        seed = cfg["variants"].get("seed", {}).get("req_per_wall_s", 0.0)
        for vname, v in cfg["variants"].items():
            speedup = (
                f"{v['req_per_wall_s'] / seed:.2f}x" if seed else "—"
            )
            print(
                f"| {cfg['name']} | {vname} | {v['virtual_miops']:.1f} "
                f"| {v['req_per_wall_s']:,.0f} | {speedup} |"
            )
else:
    print(f"\n(no {SPEED_JSON} — run `python -m benchmarks.emulator_speed`)")
