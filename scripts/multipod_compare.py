"""Single-pod vs multi-pod roofline comparison (train_4k cells)."""

import glob
import json

print("| arch | mesh | compute (s) | memory (s) | collective (s) | frac |")
print("|---|---|---|---|---|---|")
for f in sorted(glob.glob("experiments/dryrun/*__train_4k__*.json")):
    r = json.load(open(f))
    if r["status"] != "ok":
        continue
    print(
        f"| {r['arch']} | {r['mesh']} | {r['compute_s']:.2e} "
        f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
        f"| {r['roofline_fraction']:.3f} |"
    )
