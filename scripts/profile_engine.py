"""Profile one steady-state engine round: trace + per-stage cost table.

Two views of where the emulator's wall-clock goes:

  * a ``jax.profiler`` trace of one post-warmup steady-state runner
    invocation, written to ``--outdir`` (open with TensorBoard or
    Perfetto via ``xprof``);
  * a per-stage cost table: each pipeline stage (frontend fetch, timing
    model, data path, flash backend, CQ post/reap) jitted in isolation
    over a representative fetched batch and timed post-warmup, alongside
    the full ``engine_round`` — so stage costs and their sum can be
    compared against the fused round.

Stage closures honor the config's compaction/Pallas flags exactly as
``DevicePipeline.process`` threads them, so the table reflects the
pipeline actually being benchmarked. ``--assert-shares`` turns the table
into a CI smoke gate: exit 1 if any of the historical hot stages
(timing, flash, qp) exceeds ``--max-share`` of a full engine round —
the regression signature PR 8 optimized away. ``--no-trace`` skips the
profiler trace for fast smoke runs.

    PYTHONPATH=src python scripts/profile_engine.py \
        [--config local_1drive|array_4drive|remote_qos] \
        [--rounds N] [--reps N] [--outdir DIR] \
        [--no-trace] [--assert-shares] [--max-share F]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax  # noqa: E402

from benchmarks import common as C  # noqa: E402
from benchmarks.emulator_speed import _configs  # noqa: E402
from repro.core import engine, frontend, qp, timing  # noqa: E402
from repro.core import datapath, flash  # noqa: E402
from repro.core.device import DevicePipeline  # noqa: E402
from repro.core.epoch import (  # noqa: E402
    Epoch,
    admission_row_order,
    unit_ready_order,
)
from repro.core.types import PlatformModel  # noqa: E402


def _timeit(fn, *args, reps: int) -> float:
    """Mean post-warmup seconds per call of a jitted closure."""
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def stage_table(spec, reps: int):
    """Time each pipeline stage in isolation over one fetched batch."""
    cfg, ssd, wl = spec["cfg"], spec["ssd"], spec["wl"]
    plat = PlatformModel()
    pipe = DevicePipeline(cfg, ssd, plat)
    st = engine.init_state(cfg, ssd, wl)
    unit = frontend.fetch_row_units(cfg)
    # Resolve the flags the same way DevicePipeline.process does, so the
    # isolated-stage closures time the code path the engine actually runs
    # (use_pallas_segscan may be None = auto).
    pallas = cfg.resolve_pallas_segscan(ssd, plat)
    compact = cfg.use_compaction

    fetch_fn = jax.jit(lambda s: frontend.fetch(
        s.rings, s.clock, s.device.disp_time, cfg, plat
    ))
    _, disp, batch, fetch_done = jax.block_until_ready(fetch_fn(st))
    dev = dataclasses.replace(st.device, disp_time=disp)
    tbatch = dataclasses.replace(batch, arrival=fetch_done)

    # The timing closure honors cfg.lock_order the way process does:
    # under the ready-time lock the batch dispatches through the epoch's
    # admission-order row permutation (a representative one, derived
    # from this batch's post-fetch ready times).
    dispatch_order = None
    if cfg.lock_order == "ready_time" and cfg.timing_scope != "local":
        ep = Epoch.from_batch(batch, fetch_done, unit, "ring")
        dispatch_order = admission_row_order(
            unit_ready_order(ep.unit_ready(cfg.num_units)),
            ep, cfg.num_units,
        )

    rows = [("frontend.fetch", _timeit(fetch_fn, st, reps=reps))]
    rows.append(("timing.update", _timeit(
        jax.jit(lambda ts, b: timing.update(
            ts, b, ssd, cfg.mode, use_compaction=compact,
            dispatch_order=dispatch_order,
        )),
        dev.tstate, tbatch, reps=reps,
    )))
    if cfg.batched_datapath:
        rows.append(("datapath.dsa_worker_times", _timeit(
            jax.jit(lambda d, fd, b: datapath.dsa_worker_times(
                d, fd, b, cfg, plat, ssd, unit=unit
            )),
            dev.dsa_time, fetch_done, batch, reps=reps,
        )))
    else:
        rows.append(("datapath.baseline_worker_times", _timeit(
            jax.jit(lambda w, m, fd, b: datapath.baseline_worker_times(
                w, m, fd, b, cfg, plat, ssd, unit=unit,
                use_counting_sort=compact,
            )),
            dev.work_time, dev.map_time, fetch_done, batch, reps=reps,
        )))
    if ssd.flash_backend:
        rows.append(("flash.flash_stage", _timeit(
            jax.jit(lambda f, b, a: flash.flash_stage(
                f, b, a, a, ssd, use_pallas=pallas,
                use_counting_sort=compact,
                use_pallas_flash=cfg.use_pallas_flash,
            )),
            dev.flash, batch, fetch_done, reps=reps,
        )))
    rows.append(("qp.post_and_reap", _timeit(
        jax.jit(lambda c, b, d: qp.post_and_reap(
            c, b.sq_id, d, b.req_id, b.valid, cfg.qp,
            fused_sort=cfg.use_sort_plan,
            use_pallas=pallas,
            fused_scatter=compact,
            use_pallas_reap=cfg.use_pallas_reap,
        )),
        st.cq, batch, fetch_done, reps=reps,
    )))
    rows.append(("pipeline.process (stages 2-5)", _timeit(
        jax.jit(lambda d, b, fd, c: pipe.process(
            d, b, fd, unit, c, ring_layout=True
        )),
        dev, batch, fetch_done, st.cq, reps=reps,
    )))
    rows.append(("engine_round (full)", _timeit(
        jax.jit(lambda s: engine.engine_round(s, cfg, ssd, wl, plat)),
        st, reps=reps,
    )))
    return rows


# Stages whose share of a full round ``--assert-shares`` gates on: the
# three that dominated the seed profile (and that PR 8's compaction /
# fused-kernel work targeted).
HOT_STAGES = ("timing.update", "flash.flash_stage", "qp.post_and_reap")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", default="local_1drive",
                    choices=[s["name"] for s in _configs(quick=True)])
    ap.add_argument("--rounds", type=int, default=24,
                    help="engine rounds per traced runner invocation")
    ap.add_argument("--reps", type=int, default=20,
                    help="timed repetitions per stage closure")
    ap.add_argument("--outdir", default="experiments/profile",
                    help="jax.profiler trace output directory")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jax.profiler trace (fast smoke)")
    ap.add_argument("--assert-shares", action="store_true",
                    help="exit 1 if any hot stage (timing/flash/qp) "
                         "exceeds --max-share of a full engine round")
    ap.add_argument("--max-share", type=float, default=0.5,
                    help="per-stage share ceiling for --assert-shares "
                         "(fraction of engine_round; generous by design "
                         "— CI machines are noisy)")
    ap.add_argument("--lock-order", default=None,
                    choices=["program", "ready_time"],
                    help="override EngineConfig.lock_order — profile the "
                         "ready-time admission permutation's overhead "
                         "against the program-order path")
    ap.add_argument("--sanitize", action="store_true",
                    help="run one checkify-instrumented invocation "
                         "(EngineConfig.sanitize=True) before profiling "
                         "— certifies the profiled config's pipeline "
                         "invariants; the timed stage closures stay "
                         "unsanitized (checkify rewrites the program)")
    args = ap.parse_args()

    spec = dict(next(s for s in _configs(quick=False)
                     if s["name"] == args.config))
    if args.lock_order is not None:
        spec["cfg"] = spec["cfg"].replace(lock_order=args.lock_order)
    cfg, ssd, wl = spec["cfg"], spec["ssd"], spec["wl"]
    plat = PlatformModel()
    C.jit_warmup()

    # -- optional sanitized certification pass -----------------------------
    if args.sanitize:
        m = spec["num_devices"]
        if m == 1:
            s_st = engine.init_state(cfg, ssd, wl)
            s_runner = engine.make_runner(
                cfg, ssd, wl, plat, args.rounds, sanitize=True
            )
        else:
            s_st = engine.init_array_state(cfg, ssd, wl, m)
            s_runner = engine.make_array_runner(
                cfg, ssd, wl, plat, args.rounds, sanitize=True
            )
        jax.block_until_ready(s_runner(s_st))
        print(f"sanitize: {args.config} checkify-clean "
              f"({args.rounds} rounds)")

    # -- trace one post-warmup steady-state runner invocation --------------
    if not args.no_trace:
        m = spec["num_devices"]
        if m == 1:
            st = engine.init_state(cfg, ssd, wl)
            runner = engine.make_runner(cfg, ssd, wl, plat, args.rounds)
        else:
            st = engine.init_array_state(cfg, ssd, wl, m)
            runner = engine.make_array_runner(
                cfg, ssd, wl, plat, args.rounds
            )
        st = jax.block_until_ready(runner(st))  # warmup/compile round
        Path(args.outdir).mkdir(parents=True, exist_ok=True)
        try:
            with jax.profiler.trace(args.outdir):
                st = jax.block_until_ready(runner(st))
            print(f"trace: 1 x {args.rounds}-round invocation -> "
                  f"{args.outdir}")
        except Exception as e:  # noqa: BLE001 — profiling is best-effort
            print(f"trace: SKIPPED ({type(e).__name__}: {e})")

    # -- per-stage cost table ----------------------------------------------
    print(f"\nper-stage cost, config={args.config} "
          f"(mean of {args.reps} post-warmup reps, one epoch batch):")
    rows = stage_table(spec, args.reps)
    width = max(len(n) for n, _ in rows)
    total = next(dt for n, dt in rows if n.startswith("engine_round"))
    for name, dt in rows:
        print(f"  {name:<{width}}  {dt * 1e6:>10.1f} us/call "
              f"({dt / total * 100:5.1f}% of a round)")

    if args.assert_shares:
        bad = [
            (name, dt / total)
            for name, dt in rows
            if name in HOT_STAGES and dt / total > args.max_share
        ]
        if bad:
            for name, share in bad:
                print(f"FAIL: {name} is {share * 100:.1f}% of a round "
                      f"(ceiling {args.max_share * 100:.0f}%)")
            return 1
        print(f"OK: all hot stages <= {args.max_share * 100:.0f}% "
              f"of a round ({', '.join(HOT_STAGES)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
