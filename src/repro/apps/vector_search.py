"""GPU-accelerated, on-disk vector search (CAGRA-style) — paper §VII.

Graph-based ANNS where the graph index lives in accelerator memory but the
dataset VECTORS live on the emulated SSD (the on-disk regime: index >> HBM).
Each search iteration expands the best W unvisited candidates per query,
faults their neighbors' vectors in through the SwarmIO storage client
(512-byte blocks = one 128-dim fp32 vector), computes distances, and merges
the top-L candidate list.

Virtual-time accounting: per iteration the storage reads are priced by the
configured SSD model (batch × width × degree parallel reads) through the
same SQ/CQ queue-pair path as the engine; the GPU compute is a calibrated
per-iteration cost model. QPS therefore responds to device IOPS exactly
as the paper's Fig. 16 study: small batches can't generate enough
parallel I/O to exploit a faster device; larger batches can, and the
optimal search width W shifts upward with IOPS.

With ``EngineConfig.cache.enabled`` (see ``case_study(cache_sets=...)``)
a GPU-side page cache sits in front of submission: beam searches revisit
hub vectors across queries and iterations, so hits amplify QPS without
touching the device — the fig22 regime.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.client import StorageClient
from repro.core.segops import stable_argsort
from repro.core.types import (
    OP_WRITE,
    CacheConfig,
    EngineConfig,
    FabricConfig,
    PlatformModel,
    SSDConfig,
    StorageOps,
)

# Default wire for ``case_study(remote=True)``: a 64 Gbps-class link per
# drive (8000 B/us each way), 10 us RTT, MTU-batched doorbells.
REMOTE_FABRIC = FabricConfig(
    remote=True, rtt_us=10.0, tx_bytes_per_us=8000.0,
    rx_bytes_per_us=8000.0, wire_txn_us=0.2, mtu_batch=8,
    mtu_timeout_us=20.0,
)

BIG = 3e38  # python float: jnp module constants leak into jaxprs


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    dim: int = 128
    degree: int = 16            # graph out-degree
    beam_width: int = 4         # W — candidates expanded per iteration
    list_size: int = 64         # L — internal top-list length
    iterations: int = 24
    top_k: int = 10
    gpu_flops: float = 50e12    # effective distance-compute throughput
    gpu_iter_overhead_us: float = 8.0


# ---------------------------------------------------------------------------
# Index construction (exact kNN graph on synthetic data).
# ---------------------------------------------------------------------------

def build_index(
    key: jax.Array, n: int, cfg: SearchConfig
) -> Tuple[jax.Array, jax.Array]:
    """Returns (vectors (N,D), graph (N,degree)) — exact kNN graph."""
    vecs = jax.random.normal(key, (n, cfg.dim), jnp.float32)
    vecs = vecs / jnp.linalg.norm(vecs, axis=1, keepdims=True)

    def knn_row(i):
        d = jnp.sum((vecs - vecs[i]) ** 2, axis=1)
        d = d.at[i].set(BIG)
        _, idx = jax.lax.top_k(-d, cfg.degree)
        return idx

    graph = jax.lax.map(knn_row, jnp.arange(n), batch_size=256)
    return vecs, graph.astype(jnp.int32)


def ground_truth(vecs: jax.Array, queries: jax.Array, k: int) -> jax.Array:
    d = jnp.sum(
        (queries[:, None, :] - vecs[None, :, :]) ** 2, axis=-1
    )
    _, idx = jax.lax.top_k(-d, k)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# CAGRA-style batched beam search with storage-gated vector fetches.
# ---------------------------------------------------------------------------

def _merge_top(dist, idx, expanded, new_d, new_i, list_size):
    """Merge candidates; dedupe by keeping the first (sorted) occurrence."""
    all_d = jnp.concatenate([dist, new_d], axis=1)
    all_i = jnp.concatenate([idx, new_i], axis=1)
    all_e = jnp.concatenate(
        [expanded, jnp.zeros_like(new_i, bool)], axis=1
    )
    order = stable_argsort(all_d, axis=1)
    all_d = jnp.take_along_axis(all_d, order, axis=1)
    all_i = jnp.take_along_axis(all_i, order, axis=1)
    all_e = jnp.take_along_axis(all_e, order, axis=1)
    # Dedupe: mark later duplicates (same id, earlier occurrence exists).
    def dedupe_row(ids):
        eq = ids[:, None] == ids[None, :]
        earlier = jnp.tril(eq, k=-1).any(axis=1)
        return earlier

    dup = jax.vmap(dedupe_row)(all_i)
    all_d = jnp.where(dup, BIG, all_d)
    order2 = stable_argsort(all_d, axis=1)
    all_d = jnp.take_along_axis(all_d, order2, axis=1)[:, :list_size]
    all_i = jnp.take_along_axis(all_i, order2, axis=1)[:, :list_size]
    all_e = jnp.take_along_axis(all_e, order2, axis=1)[:, :list_size]
    return all_d, all_i, all_e


def search(
    queries: jax.Array,          # (B, D)
    vecs: jax.Array,             # (N, D) — the "on-disk" dataset
    graph: jax.Array,            # (N, degree)
    cfg: SearchConfig,
    ssd: SSDConfig,
    ecfg: EngineConfig | None = None,
    plat: PlatformModel | None = None,
    num_devices: int = 1,
    write_back: bool = False,
) -> dict:
    """Returns results + virtual-time QPS accounting.

    ``num_devices > 1`` stripes the vector fetches round-robin over an
    emulated M-drive array (one vmapped pipeline — the dataset exceeds a
    single drive's IOPS budget long before it exceeds its capacity).

    ``write_back=True`` persists each query's top-k result vectors to a
    result-log region through the same storage client after the search —
    the writes are priced by the full pipeline (flash program latency and
    GC back-pressure included), so QPS honestly pays for durable results.
    """
    b, d = queries.shape
    n = vecs.shape[0]
    ecfg = ecfg or EngineConfig(num_units=8, fetch_width=64)
    storage = StorageClient(ssd, ecfg, plat or PlatformModel())
    reads_per_iter = b * cfg.beam_width * cfg.degree
    if reads_per_iter % num_devices != 0:
        raise ValueError(
            f"batch*width*degree={reads_per_iter} must be divisible by "
            f"num_devices={num_devices} for striped array reads"
        )

    # Entry points: hash-spread start nodes, one per query.
    start = (
        (jnp.arange(b, dtype=jnp.uint32) * jnp.uint32(2654435761))
        % jnp.uint32(n)
    ).astype(jnp.int32)
    dist0 = jnp.full((b, cfg.list_size), BIG)
    idx0 = jnp.full((b, cfg.list_size), -1, jnp.int32)
    exp0 = jnp.zeros((b, cfg.list_size), bool)
    d_start = jnp.sum((queries - vecs[start]) ** 2, axis=1)
    dist0 = dist0.at[:, 0].set(d_start)
    idx0 = idx0.at[:, 0].set(start)

    cstate = (
        storage.init_state() if num_devices == 1
        else storage.init_array_state(num_devices)
    )
    clock0 = jnp.float32(0)

    # Per-iteration modeled GPU time: distance flops + merge overhead.
    flops_per_iter = b * cfg.beam_width * cfg.degree * d * 3
    gpu_us = flops_per_iter / cfg.gpu_flops * 1e6 + cfg.gpu_iter_overhead_us

    def body(carry, _):
        dist, idx, expd, cstate, clock = carry
        # Pick top-W unexpanded candidates.
        cand_d = jnp.where(expd | (idx < 0), BIG, dist)
        _, sel = jax.lax.top_k(-cand_d, cfg.beam_width)       # (B, W)
        sel_idx = jnp.take_along_axis(idx, sel, axis=1)       # (B, W)
        valid = jnp.take_along_axis(cand_d, sel, axis=1) < BIG
        expd = expd.at[
            jnp.arange(b)[:, None], sel
        ].set(expd[jnp.arange(b)[:, None], sel] | valid)

        # Neighbor ids (graph resides in accelerator memory).
        nbrs = graph[jnp.maximum(sel_idx, 0)]                 # (B, W, deg)
        nbrs = nbrs.reshape(b, -1)
        nvalid = jnp.repeat(valid, cfg.degree, axis=1)

        # Storage: fault in the neighbor VECTORS (1 block each).
        lba = jnp.maximum(nbrs.reshape(-1), 0)
        if num_devices == 1:
            cstate, data, done = storage.read(
                cstate, vecs, lba, clock, nvalid.reshape(-1)
            )
        else:
            cstate, data, done = storage.read_striped(
                cstate, vecs, lba, clock, nvalid.reshape(-1)
            )
        storage_done = jnp.max(done)
        fetched = data.reshape(b, -1, d)

        nd = jnp.sum((fetched - queries[:, None, :]) ** 2, axis=-1)
        nd = jnp.where(nvalid, nd, BIG)
        dist, idx, expd = _merge_top(
            dist, idx, expd, nd, nbrs, cfg.list_size
        )
        step_us = jnp.maximum(storage_done - clock, gpu_us)
        return (dist, idx, expd, cstate, clock + step_us), step_us

    (dist, idx, expd, cstate, clock), step_us = jax.lax.scan(
        body, (dist0, idx0, exp0, cstate, clock0), None,
        length=cfg.iterations,
    )
    total_us = float(clock)

    writeback_us = 0.0
    if write_back:
        # Result-log write-back goes through the unified op API: one
        # StorageOps batch per device, submitted over the same rings as
        # the read path (the legacy write/write_array wrappers are thin
        # shims over exactly this).
        k = cfg.top_k
        res_i = idx[:, :k]
        res_vecs = vecs[jnp.maximum(res_i, 0).reshape(-1)]   # (B*K, D)
        log = jnp.zeros((b * k, d), jnp.float32)
        lba = jnp.arange(b * k, dtype=jnp.int32)
        wvalid = (res_i >= 0).reshape(-1)
        if num_devices == 1:
            wops = StorageOps.make(
                lba, clock, opcode=OP_WRITE, valid=wvalid
            )
            cstate, log, _, wdone = storage.submit(
                cstate, log, wops, data=res_vecs
            )
        else:
            m = num_devices
            if (b * k) % m != 0:
                raise ValueError(
                    f"batch*top_k={b * k} must be divisible by "
                    f"num_devices={m} for array write-back"
                )
            wops = StorageOps.make(
                lba.reshape(m, -1), clock, opcode=OP_WRITE,
                valid=wvalid.reshape(m, -1),
            )
            cstate, log, _, wdone = storage.submit_array(
                cstate, log, wops, data=res_vecs.reshape(m, -1, d)
            )
            wdone = wdone.reshape(-1)
        writeback_us = max(
            float(jnp.max(jnp.where(wvalid, wdone, 0.0))) - total_us, 0.0
        )
        total_us += writeback_us

    return {
        "indices": idx[:, : cfg.top_k],
        "distances": dist[:, : cfg.top_k],
        "virtual_us": total_us,
        "qps": b / (total_us * 1e-6),
        "avg_iter_us": float(jnp.mean(step_us)),
        "gpu_iter_us": float(gpu_us),
        "reads_per_iter": b * cfg.beam_width * cfg.degree,
        "writeback_us": writeback_us,
    }


def recall_at_k(found: jax.Array, truth: jax.Array) -> float:
    """Fraction of ground-truth top-k present in results."""
    hits = (found[:, :, None] == truth[:, None, :]).any(axis=1)
    return float(jnp.mean(hits.astype(jnp.float32)))


@functools.lru_cache(maxsize=4)
def _cached_index(n: int, dim: int, degree: int, seed: int):
    cfg = SearchConfig(dim=dim, degree=degree)
    return build_index(jax.random.PRNGKey(seed), n, cfg)


def case_study(
    n: int = 4096,
    batch: int = 64,
    width: int = 4,
    iterations: int = 24,
    t_max_iops: float = 2.5e6,
    seed: int = 0,
    num_devices: int = 1,
    write_back: bool = False,
    cache_sets: int = 0,
    remote: "FabricConfig | bool | None" = None,
) -> dict:
    """One (batch, width, IOPS) cell of the paper's Fig. 16 study.

    ``cache_sets > 0`` enables the GPU-side page cache in front of the
    vector fetches (4-way set-associative, ``cache_sets`` sets) — the
    fig22 hit-rate-amplification study.

    ``remote`` reruns the case study against a *disaggregated* array:
    ``True`` puts every drive behind the default ``REMOTE_FABRIC`` link
    (pass a ``FabricConfig`` for custom wire parameters), so the vector
    fetches pay the NIC/link hop each way — combine with
    ``num_devices > 1`` for a remote all-flash array where QPS responds
    to link bandwidth, not just device IOPS.
    """
    cfg = SearchConfig(beam_width=width, iterations=iterations)
    if remote is True:
        fabric = REMOTE_FABRIC
    elif isinstance(remote, FabricConfig):
        fabric = remote
    else:
        fabric = FabricConfig()
    vecs, graph = _cached_index(n, cfg.dim, cfg.degree, seed)
    queries = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (batch, cfg.dim)
    )
    queries = queries / jnp.linalg.norm(queries, axis=1, keepdims=True)
    ssd = SSDConfig(
        t_max_iops=t_max_iops, l_min_us=50.0,
        n_instances=max(64, int(t_max_iops // 4e4)),
        num_blocks=n,
    )
    ecfg = EngineConfig(
        num_units=8, fetch_width=64,
        cache=CacheConfig(enabled=cache_sets > 0,
                          num_sets=max(cache_sets, 1)),
        fabric=fabric,
    )
    out = search(
        queries, vecs, graph, cfg, ssd, ecfg=ecfg,
        num_devices=num_devices, write_back=write_back,
    )
    truth = ground_truth(vecs, queries, cfg.top_k)
    out["recall"] = recall_at_k(out["indices"], truth)
    return out
