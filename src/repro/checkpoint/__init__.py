"""Atomic sharded checkpointing with reshard-on-load.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per leaf (host-gathered
shards) plus ``manifest.json`` (step, flattened tree keys, mesh metadata).
Writes go to ``step_<N>.tmp`` and are ``os.rename``d only after fsync —
a crashed writer never corrupts the latest checkpoint (atomic-rename
protocol). ``load`` accepts a *different* mesh/sharding tree than the one
that saved (elastic reshard-on-load): arrays are materialized host-side
and re-``device_put`` against the target shardings.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): v for k, v in flat}


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write checkpoint for ``step``. Returns the final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    names = {}
    for i, (key, val) in enumerate(flat.items()):
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(val))
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_str == "bfloat16":
            arr = arr.view(np.uint16)  # ml_dtypes (bf16) -> raw payload
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        names[key] = {"file": fname, "dtype": dtype_str}
    manifest = {
        "step": step,
        "leaves": names,
        "extra": extra or {},
        "treedef": None,  # structure re-derived from a template on load
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load(
    ckpt_dir: str,
    template,
    step: int | None = None,
    shardings=None,
):
    """Load into ``template``'s structure; ``shardings`` (same structure or
    None) re-places shards for the *current* mesh (elastic reshard)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(flat_t)
    )
    leaves = []
    for (key_path, tmpl), shard in zip(flat_t, shard_flat):
        key = jax.tree_util.keystr(key_path)
        entry = manifest["leaves"][key]
        fname = entry["file"] if isinstance(entry, dict) else entry
        arr = np.load(os.path.join(path, fname))
        if isinstance(entry, dict) and entry["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {tmpl.shape}"
            )
        arr = arr.astype(tmpl.dtype)
        leaves.append(
            jax.device_put(arr, shard) if shard is not None
            else jnp.asarray(arr)
        )
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def gc_old(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` checkpoints (and stale tmps)."""
    if not os.path.isdir(ckpt_dir):
        return
    entries = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    tmps = [d for d in entries if d.endswith(".tmp")]
    finals = [d for d in entries if not d.endswith(".tmp")]
    for d in tmps + finals[:-keep] if keep else tmps:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
