"""Architecture registry + assigned input shapes.

``get_config(arch_id, smoke=False)`` returns the exact assigned config (or
its reduced same-family smoke config). ``SHAPES`` lists the assigned
(shape_id -> spec) set shared by all LM-family archs; per-arch
applicability (e.g. long_500k only for sub-quadratic archs) is encoded in
``cells()``.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "starcoder2-3b": "starcoder2_3b",
    "command-r-plus-104b": "command_r_plus_104b",
    "yi-34b": "yi_34b",
    "gemma2-27b": "gemma2_27b",
    "xlstm-1.3b": "xlstm_13b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCHS = tuple(_MODULES)

# Archs whose decode state is sub-quadratic (recurrent state or bounded
# window) — the only ones that run long_500k per the assignment. All eight
# full-attention archs skip it (see DESIGN.md §Arch-applicability).
SUBQUADRATIC = ("xlstm-1.3b", "recurrentgemma-9b")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.FULL


def cells() -> list[tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells (40 total; long_500k is
    skipped for pure full-attention archs per the assignment, recorded as
    explicit skip cells by the dry-run driver)."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            out.append((arch, shape))
    return out


def runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True
