"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000. Parallel attn+FFN block, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab=256000,
    pattern=(ATTN,),
    parallel_block=True,                # attn and FFN share the input norm
    norm="layernorm", mlp_act="silu", mlp_gated=True, use_bias=False,
    rope="rope", rope_theta=75e6,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=256,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
