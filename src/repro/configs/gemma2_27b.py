"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. Local(4096)/global alternating attention, attn-logit
softcap 50, final softcap 30, post-norms, GeGLU. [arXiv:2408.00118; hf]"""
from repro.models.config import ATTN, ATTN_LOCAL, ModelConfig

FULL = ModelConfig(
    name="gemma2-27b",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=36864, vocab=256000,
    pattern=(ATTN_LOCAL, ATTN),          # local first, then global
    norm="rmsnorm", mlp_act="gelu", mlp_gated=True, post_norms=True,
    rope="rope", rope_theta=10000.0,
    window=4096, attn_softcap=50.0, final_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,      # query_pre_attn_scalar = d/H = 144
    tie_embeddings=True, embed_scale_by_dim=True,
)

SMOKE = FULL.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, window=32, attn_scale=16.0 ** -0.5,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
