"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048. Decoder-only over EnCodec tokens; the EnCodec frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2306.05284; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048,
    pattern=(ATTN,),
    norm="layernorm", mlp_act="gelu", mlp_gated=False, use_bias=True,
    rope="none",                         # learned/sinusoidal pos in frontend
    modality="audio",
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
