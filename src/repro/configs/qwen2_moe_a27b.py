"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=151936,
    pattern=(ATTN,),
    norm="rmsnorm", mlp_act="silu", mlp_gated=True,
    qkv_bias=True,                      # qwen1.5/qwen2-family q/k/v biases
    rope="rope", rope_theta=1e6,
    n_experts=60, top_k=4, d_expert=1408,
    n_shared_experts=4, d_shared_expert=4 * 1408,   # fused shared branch
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=32, vocab=256, n_experts=6, top_k=2, d_expert=32,
    n_shared_experts=2, d_shared_expert=64,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
