"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. M-RoPE, dynamic resolution; the vision tower is a STUB
(input_specs provides patch embeddings + (3,B,S) position ids).
[arXiv:2409.12191; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=29568, vocab=152064,
    pattern=(ATTN,),
    norm="rmsnorm", mlp_act="silu", mlp_gated=True,
    qkv_bias=True,
    rope="mrope", rope_theta=1e6, mrope_sections=(16, 24, 24),
    modality="vision",
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=256, mrope_sections=(2, 1, 1),
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
