"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab=151936,
    pattern=(ATTN,),
    norm="rmsnorm", mlp_act="silu", mlp_gated=True,
    qk_norm=True,                       # qwen3 per-head q/k RMSNorm
    rope="rope", rope_theta=1e6,
    n_experts=128, top_k=8, d_expert=768,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=256, n_experts=8, top_k=2, d_expert=32,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
