"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000. Griffin: RG-LRU + local attention, 1:2.
[arXiv:2402.19427; unverified]"""
from repro.models.config import ATTN_LOCAL, RGLRU, ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab=256000,
    pattern=(RGLRU, RGLRU, ATTN_LOCAL),  # 12 periods + 2 remainder RG-LRU
    norm="rmsnorm", mlp_act="gelu", mlp_gated=True,
    rope="rope", rope_theta=10000.0,
    window=2048,
    conv_width=4,
    tie_embeddings=True, embed_scale_by_dim=True,
)

SMOKE = FULL.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab=256, window=32,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
