"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152. GQA, RoPE, biases, plain-GELU MLP, LayerNorm.
[arXiv:2402.19173; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49152,
    pattern=(ATTN,),
    norm="layernorm", mlp_act="gelu", mlp_gated=False, use_bias=True,
    rope="rope", rope_theta=999999.0,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
