"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks at 7:1 (xLSTM[7:1]). [arXiv:2405.04517; unverified]"""
from repro.models.config import MLSTM, SLSTM, ModelConfig

FULL = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_head=512,
    d_ff=0, vocab=50304,
    pattern=(MLSTM,) * 7 + (SLSTM,),     # 7:1 mLSTM:sLSTM
    norm="layernorm",
    rope="none",
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
    vocab=256,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
