"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000. Llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.models.config import ATTN, ModelConfig

FULL = ModelConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000,
    pattern=(ATTN,),
    norm="rmsnorm", mlp_act="silu", mlp_gated=True,
    rope="rope", rope_theta=5e6,
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=256,
    dtype="float32", loss_chunk=64, attn_chunk=64, remat=False,
)
