"""GPU-side set-associative page cache (pipeline stage 0).

Models the accelerator-resident readahead/page cache that GPU-centric
storage stacks (BaM-style) put in front of the submission path: every
read first probes an HBM-resident set-associative tag array, and a hit
is served at GPU-local latency without ever posting an SQE — it
consumes no ring slot, no frontend transaction, and no device time.
Delivered application IOPS therefore amplify with the hit rate on
skewed (Zipf) and re-read-heavy workloads (fig22), which is exactly the
regime the paper's vector-search case study runs in.

The cache is virtual-time state like everything else in the pipeline:
a ``CacheState`` pytree (vmap-able over emulated devices) with
vectorized, epoch-batched ``lookup``/``insert``. Replacement is FIFO
per set (a round-robin victim cursor); ``readahead`` optionally fills
the next R sequential blocks alongside every miss fill. Lookups within
an epoch probe the epoch-start tags — the same lazy-update convention
the timing and flash stages use.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import segment_rank
from repro.core.types import CacheConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CacheState:
    """Tag array for one device's GPU-side page cache."""

    tags: jax.Array  # (S, W) i32 cached LBA per way, -1 = empty
    rr: jax.Array  # (S,) i32 FIFO victim cursor per set

    @staticmethod
    def init(ccfg: CacheConfig) -> "CacheState":
        return CacheState(
            tags=jnp.full((ccfg.num_sets, ccfg.ways), -1, jnp.int32),
            rr=jnp.zeros((ccfg.num_sets,), jnp.int32),
        )

    @property
    def num_sets(self) -> int:
        return self.tags.shape[0]

    @property
    def ways(self) -> int:
        return self.tags.shape[1]


def set_of(lba: jax.Array, ccfg: CacheConfig) -> jax.Array:
    """Set index for an LBA — direct modulo, so sequential blocks land
    in consecutive sets (readahead fills never collide within a run)."""
    return (lba % jnp.int32(ccfg.num_sets)).astype(jnp.int32)


def lookup(
    state: CacheState,
    lba: jax.Array,  # (N,) i32
    valid: jax.Array,  # (N,) bool
    ccfg: CacheConfig,
) -> jax.Array:
    """Vectorized probe. Returns hit (N,) bool against epoch-start tags."""
    ways = state.tags[set_of(lba, ccfg)]  # (N, W)
    hit = jnp.any(ways == lba[:, None], axis=1)
    return hit & valid & (lba >= 0)


def _insert_once(
    state: CacheState, lba: jax.Array, fill: jax.Array, ccfg: CacheConfig
) -> CacheState:
    """Insert one batch of fills (already deduplicated against the tags
    by the caller). Multiple fills mapping to one set take consecutive
    victim ways (FIFO order preserved across epochs via ``rr``)."""
    s = ccfg.num_sets
    key = jnp.where(fill, set_of(lba, ccfg), jnp.int32(s))
    rank = segment_rank(key)
    row = jnp.clip(key, 0, s - 1)
    way = (state.rr[row] + rank) % jnp.int32(ccfg.ways)
    way = jnp.where(fill, way, jnp.int32(ccfg.ways))  # drop non-fills
    counts = jax.ops.segment_sum(
        fill.astype(jnp.int32), key, num_segments=s + 1
    )[:s]
    return CacheState(
        tags=state.tags.at[row, way].set(lba, mode="drop"),
        rr=(state.rr + counts) % jnp.int32(ccfg.ways),
    )


def insert(
    state: CacheState,
    lba: jax.Array,  # (N,) i32 blocks that just became GPU-resident
    valid: jax.Array,  # (N,) bool
    ccfg: CacheConfig,
) -> CacheState:
    """Fill completed reads (plus optional sequential readahead) into the
    cache. Already-present blocks are skipped so re-reads do not burn
    victim ways; duplicate fills *within* one epoch may transiently
    occupy two ways of a set (epoch-batched semantics — harmless, the
    FIFO cursor recycles them first).
    """
    for r in range(ccfg.readahead + 1):
        fill_lba = lba + jnp.int32(r)
        fill = valid & (fill_lba >= 0)
        fill = fill & ~lookup(state, fill_lba, fill, ccfg)
        state = _insert_once(state, fill_lba, fill, ccfg)
    return state


def serve(
    state: CacheState,
    lba: jax.Array,  # (N,) i32 proposed read addresses
    is_read: jax.Array,  # (N,) bool row is a valid read request
    t_submit: jax.Array,  # (N,) f32 virtual submission times
    ccfg: CacheConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Stage-0 filter: probe the batch before SQ submission.

    Returns (hit (N,) bool, done (N,) f32): hit rows complete at
    ``t_submit + hit_us`` without entering the rings; the caller submits
    only the misses.
    """
    hit = lookup(state, lba, is_read, ccfg)
    done = jnp.where(hit, t_submit + jnp.float32(ccfg.hit_us), 0.0)
    return hit, done
