"""Accelerator-initiated storage client (virtual time).

Applications (the SSD-backed KV tier, the vector-search case study) do not
need the full SQ-ring machinery — they issue *batched* block reads and need
(a) the data, functionally, and (b) faithful virtual-time completion times
under a configured device model. ``StorageClient`` provides exactly that:
each ``read`` models GPU-initiated submission across ``num_sqs`` queues,
SwarmIO's coalesced fetch + aggregated timing + DSA-batched data path, and
returns per-request completion times plus the gathered blocks.

This is the "GPU-initiated I/O" surface the paper's case study uses: the
application decides *when* to issue (its own virtual clock), the client
answers *when the data is ready*.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import timing
from repro.core.segops import queueing_scan
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    SSDConfig,
    TimingState,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClientState:
    """Virtual-time device state carried across application steps."""

    tstate: TimingState
    disp_time: jax.Array  # (U,) dispatcher cursors
    dsa_time: jax.Array   # (U,) DSA engine cursors

    @staticmethod
    def init(ssd: SSDConfig, num_units: int) -> "ClientState":
        return ClientState(
            tstate=TimingState.init(ssd.n_instances),
            disp_time=jnp.zeros((num_units,), jnp.float32),
            dsa_time=jnp.zeros((num_units,), jnp.float32),
        )


@dataclasses.dataclass(frozen=True)
class StorageClient:
    ssd: SSDConfig
    cfg: EngineConfig
    plat: PlatformModel = PlatformModel()

    def read(
        self,
        state: ClientState,
        flash: jax.Array,      # (num_blocks, block_words)
        lba: jax.Array,        # (N,) i32 block addresses
        t_submit: jax.Array,   # () or (N,) f32 virtual submission time(s)
        valid: jax.Array | None = None,
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Issue N block reads at ``t_submit``.

        Returns (state', data (N, block_words), completion_times (N,)).
        """
        n = lba.shape[0]
        u = state.disp_time.shape[0]
        if valid is None:
            valid = jnp.ones((n,), bool)
        t_submit = jnp.broadcast_to(jnp.asarray(t_submit, jnp.float32), (n,))

        # --- frontend: coalesced fetch, requests dealt round-robin to units.
        per_unit = -(-n // u)  # ceil
        idx = jnp.arange(n, dtype=jnp.int32)
        unit = idx // per_unit
        rank = idx % per_unit
        txn = jnp.float32(
            self.plat.txn_base_us
            if self.cfg.transport == "p2p" else self.plat.host_txn_base_us
        )
        bw = jnp.float32(
            self.plat.link_bytes_per_us
            if self.cfg.transport == "p2p" else self.plat.host_bytes_per_us
        )
        f = self.cfg.fetch_width
        if self.cfg.coalesced:
            # One transaction per fetch_width entries per unit.
            n_txn = rank // f + 1
            fetch_done = (
                jnp.maximum(t_submit, state.disp_time[unit])
                + n_txn.astype(jnp.float32) * txn
                + (rank + 1).astype(jnp.float32) * self.plat.sqe_bytes / bw
            )
        else:
            fetch_done = (
                jnp.maximum(t_submit, state.disp_time[unit])
                + (rank + 1).astype(jnp.float32)
                * (txn + self.plat.sqe_bytes / bw)
            )
        fetch_done = jnp.where(valid, fetch_done, 0.0)
        disp_time = jnp.maximum(
            jax.ops.segment_max(
                jnp.where(valid, fetch_done, 0.0), unit, num_segments=u
            ),
            state.disp_time,
        )

        # --- timing model (aggregated, one shared-state update).
        if self.ssd.routing == "lba_hash":
            inst = timing.lba_hash_instance(lba, self.ssd.n_instances)
            rr = state.tstate.rr
        else:
            inst, rr = timing.assign_rr(
                state.tstate.rr, valid, self.ssd.n_instances
            )
        target, new_busy = timing.aggregated_batch_times(
            state.tstate.busy_until, fetch_done, inst, valid, self.ssd
        )

        # --- data path: batched DSA copies, pipelined per unit.
        issue = (
            self.plat.dsa_desc_issue_us
            + self.plat.dsa_batch_setup_us / max(self.cfg.fetch_width, 1)
        )
        cost = jnp.where(
            valid,
            self.ssd.block_bytes / self.plat.dsa_bytes_per_us + 0.01,
            0.0,
        )
        heads = jnp.concatenate(
            [jnp.ones((1,), bool), unit[1:] != unit[:-1]]
        )
        busy = queueing_scan(
            fetch_done + issue, cost, heads, state.dsa_time[unit]
        )
        dsa_time = jnp.maximum(
            jax.ops.segment_max(busy, unit, num_segments=u), state.dsa_time
        )

        done = jnp.where(valid, jnp.maximum(target, busy), 0.0)
        data = flash[jnp.where(valid, lba, 0)]
        new_state = ClientState(
            tstate=TimingState(new_busy, rr), disp_time=disp_time,
            dsa_time=dsa_time,
        )
        return new_state, data, done
