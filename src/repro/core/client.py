"""Accelerator-initiated storage client (virtual time).

Applications (the SSD-backed KV tier, the vector-search case study) issue
*batched* block reads and writes and need (a) the data moved,
functionally, and (b) faithful virtual-time completion times under a
configured device model. ``StorageClient`` provides exactly that.

**The op API.** ``submit(state, flash, ops)`` is the single entry point:
``ops`` is a ``StorageOps`` batch (``core/types.py``) carrying opcode,
LBA, QoS tenant, and submission clock per slot, and one implementation
runs the rings -> pipeline -> CQ path for the whole (possibly mixed
read/write, multi-tenant) batch. ``submit_array`` vmaps it over an
M-drive array and ``submit_striped`` round-robins a flat op batch over
the array's drives. Everything else is a thin wrapper:

    read / write                  homogeneous single-drive batches
    read_array / write_array      per-drive (M, N) batches, one vmap
    read_striped                  flat batch striped over W <= M drives
    read_replicated               least-loaded replica routing

**Migration note.** Before the op API, the six wrappers were six
separate entry points growing divergent kwargs; they are now sugar over
``submit(ops)`` and pinned bit-exact against it by
``tests/test_client_api.py``. New call sites (and any caller mixing
reads with writes or tenants in one batch) should build a
``StorageOps`` and call ``submit``/``submit_array``/``submit_striped``
directly; the wrappers remain for the common homogeneous cases. The
ring-less ``DevicePipeline.fetch_direct``/``submit_direct`` shortcuts
were removed in PR 9 (only the underscore test-only names remain).

The client runs the *same queue-pair path as the engine* at every layer:
each ``submit`` posts SQEs into real ``SQRings`` (requests dealt
round-robin across the service units' SQs), the configured frontend
fetches them (``frontend.fetch_distributed``/``fetch_centralized`` — the
identical ring-fetch code ``engine_round`` runs), the shared
``DevicePipeline.process`` prices stages 2-4, and every completion is
posted to the paired CQ and reaped by the consumer (stage 5, qp.py).
Batches larger than one fetch window (``num_sqs * fetch_width``) drain
the rings over multiple statically unrolled fetch passes. The client
carries no cost formulas of its own, and the test suite asserts its
completion times reproduce ``engine_round`` bit-exactly for the same
request stream.

Stage 0: with ``EngineConfig.cache.enabled`` a GPU-side page cache
(cache.py) filters read hits *before* SQ submission — they complete at
GPU-local latency and never touch the rings or the device; completed
reads and writes fill the cache (write-allocate).

The array entry points extend the same program to an M-drive array: the
per-device pipeline is ``vmap``-ed over a leading device axis, so one
jit program prices the whole array (paper-title 100-MIOPS regime at
M x 40-MIOPS drives). Striped submission accepts any batch size (ragged
tails pad with invalid slots) and a ``stripe_width``; replicated reads
home block b's R copies on drives ``(b + r) % M`` and route each read
to the least-loaded candidate (the drive's own instance backlog, plus
its RX link and shared-switch cursors on a remote array). With
``EngineConfig.fabric.remote`` the drives are *remote*: every request
pays the NIC/link hop — and, when configured, the shared-switch hop
(fabric.py) — exactly as ``engine_round`` prices it. Every entry point
takes a ``tenant=`` QoS class (scalar or per request) that the
fabric's weighted-fair arbiter (``FabricConfig.qos_weights``)
arbitrates between; ``t_submit`` defaults to virtual time zero in every
entry point alike.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as cache_mod
from repro.core import frontend
from repro.core.cache import CacheState
from repro.core.device import (
    DevicePipeline,
    DeviceState,
    init_array_state as _stack_states,
)
from repro.core.frontend import SQRings
from repro.core.segops import segment_rank, stable_argsort
from repro.core.types import (
    OP_WRITE,
    EngineConfig,
    PlatformModel,
    SSDConfig,
    StorageOps,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClientState:
    """Virtual-time device state carried across application steps."""

    dev: DeviceState
    cache: "CacheState | None" = None   # stage-0 GPU page cache

    @staticmethod
    def init(ssd: SSDConfig, num_units: int, workers_per_unit: int = 1,
             num_tenants: int = 1) -> "ClientState":
        """Manual-shape constructor (escape hatch). Prefer
        ``StorageClient.init_state``, which derives unit/worker/tenant
        counts from the same EngineConfig the pipeline prices with —
        passing counts that disagree with the config silently prices a
        different device (and a ``num_tenants`` below
        ``cfg.fabric.num_tenants`` mis-shapes the per-tenant fabric
        cursors).
        """
        return ClientState(
            dev=DeviceState.init(
                ssd, num_units, workers_per_unit, num_tenants
            )
        )



@dataclasses.dataclass(frozen=True)
class StorageClient:
    ssd: SSDConfig
    cfg: EngineConfig
    plat: PlatformModel = PlatformModel()

    @property
    def pipeline(self) -> DevicePipeline:
        return DevicePipeline(self.cfg, self.ssd, self.plat)

    def init_state(self) -> ClientState:
        """Fresh state with unit/worker shapes derived from ``cfg`` — the
        exact shapes ``engine_round`` prices with (parity-safe for every
        frontend/datapath combination)."""
        return ClientState(
            dev=self.pipeline.init_state(),
            cache=(
                CacheState.init(self.cfg.cache)
                if self.cfg.cache.enabled else None
            ),
        )

    def init_array_state(self, num_devices: int) -> ClientState:
        """Fresh stacked state for an M-drive array, cfg-derived shapes."""
        return _stack_states(lambda _: self.init_state(), num_devices)

    # -- the shared SQ -> pipeline -> CQ ring path --------------------------
    def _submit_through_rings(
        self,
        dev: DeviceState,
        lba: jax.Array,        # (N,) i32
        t_submit: jax.Array,   # (N,) f32
        valid: jax.Array,      # (N,) bool
        opcode: jax.Array,     # (N,) i32
        tenant: jax.Array | None = None,  # (N,) i32 QoS class
    ) -> Tuple[DeviceState, jax.Array]:
        """Post a flat batch as SQEs, fetch + process + reap via the CQs.

        The exact engine path: entries are dealt round-robin across the
        service units' SQs (time-sorted, so rings stay in-order), the
        configured ring frontend fetches them in as many passes as the
        fetch window requires, and completion times are the CQ-reaped
        times. Each fetch pass flows through ``DevicePipeline.process``
        as one admission epoch (``core/epoch.py``) — under
        ``cfg.lock_order="ready_time"`` the units of a client batch
        acquire the stage-2a lock by post-TX batch arrival exactly as
        the engine's do. Returns (dev', done (N,) in the original
        request order).
        """
        cfg, plat, pipe = self.cfg, self.plat, self.pipeline
        n = lba.shape[0]
        q, f = cfg.num_sqs, cfg.fetch_width
        if n > q * cfg.sq_depth:
            raise ValueError(
                f"batch of {n} requests exceeds ring capacity "
                f"num_sqs*sq_depth={q * cfg.sq_depth}"
            )

        # Deal time-sorted requests across SQs; req_id carries the
        # original index so completions scatter back to request order.
        order = stable_argsort(t_submit)
        sq_id = frontend.deal_sqs(n, cfg)
        zeros = jnp.zeros((n,), jnp.int32)
        if tenant is None:
            tenant = zeros
        rings = SQRings.empty(q, cfg.sq_depth)
        rings = frontend.submit(
            rings, sq_id, t_submit[order], opcode[order], lba[order],
            jnp.ones((n,), jnp.int32), zeros, order.astype(jnp.int32),
            valid[order], tenant=tenant[order],
        )

        cq = pipe.init_cq()
        row_unit = frontend.fetch_row_units(cfg)
        clock = jnp.max(jnp.where(valid, t_submit, 0.0))
        done = jnp.zeros((n,), jnp.float32)
        passes = -(-n // (q * f))  # ceil: fetch window per pass
        for _ in range(passes):
            # Dispatchers poll again as soon as they are free (all
            # entries are already posted and visible).
            clock = jnp.maximum(clock, jnp.max(dev.disp_time))
            rings, disp_time, batch, fetch_done = frontend.fetch(
                rings, clock, dev.disp_time, cfg, plat
            )
            dev = dataclasses.replace(dev, disp_time=disp_time)
            # Ring-fetched batches are SQ-major (the same promise the
            # engine round relies on), so compaction's block tricks hold.
            dev, cq, res = pipe.process(
                dev, batch, fetch_done, row_unit, cq, ring_layout=True
            )
            idx = jnp.where(batch.valid, batch.req_id, n)
            done = done.at[idx].set(res.reaped, mode="drop")
        return dev, done

    # -- the unified op API --------------------------------------------------
    def submit(
        self,
        state: ClientState,
        flash: jax.Array,       # (num_blocks, block_words)
        ops: StorageOps,        # flat (N,) op batch (possibly mixed r/w)
        data: jax.Array | None = None,   # (N, block_words) write payloads
        with_data: bool = False,
    ) -> Tuple[ClientState, jax.Array, "jax.Array | None", jax.Array]:
        """THE client entry point: one batched op submission.

        Every slot of ``ops`` carries its own opcode, LBA, tenant class,
        and submission clock; the whole batch goes down the single
        rings -> pipeline -> CQ implementation (mixed read/write batches
        are priced exactly like the engine's mixed workloads). Returns
        ``(state', flash', data_out, done)``:

        * ``flash'`` — ``flash`` with the valid write slots' ``data``
          rows scattered in (unchanged when ``data is None``; duplicate
          LBAs within a batch land unspecified — XLA scatter);
        * ``data_out`` — the gathered block rows for every valid slot
          when ``with_data=True`` (reads observe this batch's writes in
          the functional store), else ``None``;
        * ``done`` — per-slot consumer-observed completion times.

        Stage-0 cache semantics: read hits complete at ``hit_us`` and
        never post an SQE; every valid completion (read or write) fills
        the cache (write-allocate).
        """
        lba = ops.lba.astype(jnp.int32)
        valid, t_submit = ops.valid, ops.t_submit
        is_write = ops.opcode == OP_WRITE

        cstate = state.cache
        submit_valid = valid
        if self.cfg.cache.enabled:
            hit, hit_done = cache_mod.serve(
                cstate, lba, valid, t_submit, self.cfg.cache
            )
            hit = hit & ~is_write       # only reads are served by a hit
            submit_valid = valid & ~hit

        dev, done = self._submit_through_rings(
            state.dev, lba, t_submit, submit_valid, ops.opcode, ops.tenant
        )
        if self.cfg.cache.enabled:
            done = jnp.where(hit, hit_done, done)
            cstate = cache_mod.insert(cstate, lba, valid, self.cfg.cache)

        if data is not None:
            dst = jnp.where(valid & is_write, lba, flash.shape[0])
            flash = flash.at[dst].set(data, mode="drop")
        out = flash[jnp.where(valid, lba, 0)] if with_data else None
        return ClientState(dev=dev, cache=cstate), flash, out, done

    def submit_array(
        self,
        state: ClientState,     # stacked: every leaf has a leading (M,) axis
        flash: jax.Array,       # (num_blocks, block_words) — shared store
        ops: StorageOps,        # (M, N) per-device op batches
        data: jax.Array | None = None,   # (M, N, block_words) payloads
        with_data: bool = False,
    ) -> Tuple[ClientState, jax.Array, "jax.Array | None", jax.Array]:
        """``submit`` vmapped over an M-drive array (one jit program).

        Virtual-time pricing runs per drive inside the vmap; the
        functional scatter/gather against the shared block store happens
        once at the array level (identical semantics, no M store
        copies). Returns ``(state', flash', data_out, done)`` with
        ``done`` shaped (M, N).
        """
        m, n = ops.lba.shape

        def one(st, ops_d):
            st, _, _, done = self.submit(st, flash, ops_d)
            return st, done

        state, done = jax.vmap(one)(state, ops)
        if data is not None:
            dst = jnp.where(
                ops.valid & (ops.opcode == OP_WRITE),
                ops.lba, flash.shape[0],
            ).reshape(-1)
            flash = flash.at[dst].set(
                data.reshape((m * n,) + data.shape[2:]), mode="drop"
            )
        out = (
            flash[jnp.where(ops.valid, ops.lba, 0)] if with_data else None
        )
        return state, flash, out, done

    def submit_striped(
        self,
        state: ClientState,     # stacked array state (M devices)
        flash: jax.Array,
        ops: StorageOps,        # flat (N,) op batch — any N
        data: jax.Array | None = None,   # (N, block_words) write payloads
        stripe_width: int | None = None,
        with_data: bool = False,
    ) -> Tuple[ClientState, jax.Array, "jax.Array | None", jax.Array]:
        """Stripe a flat op batch round-robin over the array's drives.

        Op i goes to drive ``i % W`` with ``W = stripe_width`` (default:
        all M drives) — fixed interleaved placement over the first W
        drives; the remaining drives see an empty batch. Any batch size
        works: a ragged tail stripe is padded with invalid slots that
        never touch the rings or the device, and ``done``/``data_out``
        return in the original op order.
        """
        m = jax.tree.leaves(state.dev)[0].shape[0]
        w = m if stripe_width is None else stripe_width
        if not 1 <= w <= m:
            raise ValueError(
                f"stripe_width={w} must be in [1, M={m}] — a stripe "
                "cannot span more drives than the array holds"
            )
        n = ops.lba.shape[0]
        cols = -(-n // w)          # ceil: ring slots per striped drive
        pad = cols * w - n

        # (N, ...) -> (M, cols, ...): op i = stripe (i % W, i // W); the
        # pad tail and the M - W unstriped drives are invalid slots.
        def to_dev(x, fill):
            tail = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
            x = jnp.concatenate([x, tail])
            x = jnp.swapaxes(x.reshape((cols, w) + x.shape[1:]), 0, 1)
            if w < m:
                rest = jnp.full((m - w, cols) + x.shape[2:], fill, x.dtype)
                x = jnp.concatenate([x, rest])
            return x

        ops2d = StorageOps(
            opcode=to_dev(ops.opcode, 0),
            lba=to_dev(ops.lba.astype(jnp.int32), 0),
            t_submit=to_dev(ops.t_submit, 0.0),
            tenant=to_dev(ops.tenant, 0),
            valid=to_dev(ops.valid, False),
        )
        data2d = None if data is None else to_dev(data, 0)
        state, flash, _, done2d = self.submit_array(
            state, flash, ops2d, data=data2d
        )
        done = jnp.swapaxes(done2d[:w], 0, 1).reshape(cols * w)[:n]
        out = (
            flash[jnp.where(ops.valid, ops.lba, 0)] if with_data else None
        )
        return state, flash, out, done

    # -- legacy entry points: thin wrappers over submit ----------------------
    def read(
        self,
        state: ClientState,
        flash: jax.Array,      # (num_blocks, block_words)
        lba: jax.Array,        # (N,) i32 block addresses
        t_submit: "jax.Array | float" = 0.0,   # () or (N,) f32
        valid: jax.Array | None = None,
        with_data: bool = True,
        tenant: "jax.Array | int" = 0,   # () or (N,) i32 QoS class
    ) -> Tuple[ClientState, "jax.Array | None", jax.Array]:
        """Issue N block reads at ``t_submit`` through the SQ/CQ rings.

        Sugar for ``submit`` with an all-read op batch. Returns
        (state', data (N, block_words), completion_times (N,)).
        ``with_data=False`` skips the functional gather and returns
        ``None`` data — for callers that gather once themselves.
        """
        ops = StorageOps.make(lba, t_submit, tenant=tenant, valid=valid)
        state, _, data, done = self.submit(
            state, flash, ops, with_data=with_data
        )
        return state, data, done

    def write(
        self,
        state: ClientState,
        flash: jax.Array,      # (num_blocks, block_words)
        data: jax.Array,       # (N, block_words) blocks to persist
        lba: jax.Array,        # (N,) i32 destination block addresses
        t_submit: "jax.Array | float" = 0.0,   # () or (N,) f32
        valid: jax.Array | None = None,
        tenant: "jax.Array | int" = 0,   # () or (N,) i32 QoS class
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Issue N block writes at ``t_submit`` through the SQ/CQ rings.

        Sugar for ``submit`` with an all-write op batch — the OP_WRITE
        opcode routes stage 4 to flash programs (and GC once the free
        pool drains), so sustained writes are honestly slower than
        reads. Writes always reach the device (durability); with the
        cache enabled they fill it (write-allocate). Returns (state',
        flash' with the blocks scattered in, completion_times (N,)).
        If the batch writes the same LBA more than once, which copy
        lands is unspecified (XLA scatter with duplicate indices).
        """
        ops = StorageOps.make(
            lba, t_submit, opcode=OP_WRITE, tenant=tenant, valid=valid
        )
        state, flash, _, done = self.submit(state, flash, ops, data=data)
        return state, flash, done

    def read_array(
        self,
        state: ClientState,    # stacked: every leaf has a leading (M,) axis
        flash: jax.Array,      # (num_blocks, block_words) — shared store
        lba: jax.Array,        # (M, N) i32 per-device block addresses
        t_submit: "jax.Array | float" = 0.0,   # (), (M,), or (M, N) f32
        valid: jax.Array | None = None,   # (M, N) bool
        with_data: bool = True,
        tenant: "jax.Array | int" = 0,    # scalar or (M, N) i32 QoS class
    ) -> Tuple[ClientState, "jax.Array | None", jax.Array]:
        """Per-device batched reads over an M-drive array, one vmap.

        Sugar for ``submit_array`` with an all-read op batch.
        """
        t_submit = jnp.asarray(t_submit, jnp.float32)
        if t_submit.ndim == 1:
            t_submit = t_submit[:, None]
        ops = StorageOps.make(lba, t_submit, tenant=tenant, valid=valid)
        state, _, data, done = self.submit_array(
            state, flash, ops, with_data=with_data
        )
        return state, data, done

    def write_array(
        self,
        state: ClientState,    # stacked: every leaf has a leading (M,) axis
        flash: jax.Array,      # (num_blocks, block_words) — shared store
        data: jax.Array,       # (M, N, block_words) per-device payloads
        lba: jax.Array,        # (M, N) i32 per-device block addresses
        t_submit: "jax.Array | float" = 0.0,   # (), (M,), or (M, N) f32
        valid: jax.Array | None = None,   # (M, N) bool
        tenant: "jax.Array | int" = 0,    # scalar or (M, N) i32 QoS class
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Per-device batched writes over an M-drive array, one vmap.

        Sugar for ``submit_array`` with an all-write op batch: pricing
        is per drive (each device's pipeline carries its own chips/GC
        state); the functional scatter lands once in the shared block
        store. If multiple rows (within or across devices) target the
        same LBA, which copy lands is unspecified (XLA scatter with
        duplicate indices) — partition the address space across drives
        when that matters.
        """
        t_submit = jnp.asarray(t_submit, jnp.float32)
        if t_submit.ndim == 1:
            t_submit = t_submit[:, None]
        ops = StorageOps.make(
            lba, t_submit, opcode=OP_WRITE, tenant=tenant, valid=valid
        )
        state, flash, _, done = self.submit_array(
            state, flash, ops, data=data
        )
        return state, flash, done

    def read_striped(
        self,
        state: ClientState,    # stacked array state (M devices)
        flash: jax.Array,
        lba: jax.Array,        # (N,) i32 — any N
        t_submit: "jax.Array | float" = 0.0,   # () or (N,) f32
        valid: jax.Array | None = None,
        stripe_width: int | None = None,
        tenant: "jax.Array | int" = 0,   # () or (N,) i32 QoS class
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Stripe a flat read batch round-robin over the array's drives.

        Sugar for ``submit_striped`` with an all-read op batch; see it
        for the placement rule and ragged-tail padding.
        """
        ops = StorageOps.make(lba, t_submit, tenant=tenant, valid=valid)
        state, _, data, done = self.submit_striped(
            state, flash, ops, stripe_width=stripe_width, with_data=True
        )
        return state, data, done

    def read_replicated(
        self,
        state: ClientState,    # stacked array state (M devices)
        flash: jax.Array,
        lba: jax.Array,        # (N,) i32 — any N
        t_submit: "jax.Array | float" = 0.0,   # () or (N,) f32
        valid: jax.Array | None = None,
        replicas: int = 2,
        tenant: "jax.Array | int" = 0,   # () or (N,) i32 QoS class
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Replica-read over an M-drive array with least-loaded routing.

        Block b's R replicas live on drives ``(b + r) % M`` (chained
        declustering), and each read is routed to the candidate that is
        least loaded: the drive's own occupancy (its timing-model
        instance backlog) plus — on a remote array — its fabric RX link
        cursor and its shared-switch RX cursor, plus the estimated time
        of the work already routed to it within this batch. The
        device-side term keeps routing load-aware on *local* arrays
        too, where the wire cursors never advance (they used to be the
        only signal, which left local routing blind to busy drives).
        Returns (state', data, done) in the original request order.
        """
        m = jax.tree.leaves(state.dev)[0].shape[0]
        if not 1 <= replicas <= m:
            raise ValueError(
                f"replicas={replicas} must be in [1, M={m}] — a block "
                "cannot have more replicas than the array has drives"
            )
        n = lba.shape[0]
        lba = lba.astype(jnp.int32)
        if valid is None:
            valid = jnp.ones((n,), bool)
        t_submit = jnp.broadcast_to(jnp.asarray(t_submit, jnp.float32), (n,))

        # Load signal and per-request increment, both in us of backlog.
        # Device side: mean instance occupancy, growing by one service
        # slot (1e6 / t_max_iops us) per routed read. Remote side adds
        # the RX link + switch cursors and the frame's wire time (frame
        # bytes at the binding bandwidths plus the amortized wire-
        # transaction cost); a zero-cost wire contributes nothing and
        # the device-side term alone still balances — bit-identical to
        # a local array, as the parity suite asserts.
        fab = self.cfg.fabric
        load0 = jnp.mean(state.dev.tstate.busy_until, axis=-1)
        est = 1e6 / self.ssd.t_max_iops
        if fab.remote:
            # The link frontier is the latest per-tenant cursor.
            load0 = load0 + jnp.max(state.dev.fabric.rx_busy, axis=-1)
            est += fab.wire_txn_us / fab.mtu_batch
            frame = fab.cqe_bytes + self.ssd.block_bytes
            if math.isfinite(fab.rx_bytes_per_us):
                est += frame / fab.rx_bytes_per_us
            if fab.switched:
                load0 = load0 + jnp.max(
                    state.dev.fabric.switch_rx, axis=-1
                )
                est += frame / fab.switch_share_bytes_per_us
        cand = (
            lba[:, None] + jnp.arange(replicas, dtype=jnp.int32)[None, :]
        ) % m                                            # (N, R)

        def route(load, x):
            cand_i, v = x
            d = cand_i[jnp.argmin(load[cand_i])]
            load = jnp.where(
                v, load.at[d].add(jnp.float32(est), mode="drop"), load
            )
            return load, jnp.where(v, d, jnp.int32(m))

        _, drive = jax.lax.scan(route, load0, (cand, valid))

        # Scatter each request into its drive's batch slot (rank =
        # arrival order within the drive), fan out through the array
        # read, and gather completions back to request order.
        rank = segment_rank(drive)
        row = jnp.clip(drive, 0, m - 1)
        col = jnp.where(valid, rank, n)

        def scat(x, fill, dtype):
            base = jnp.full((m, n), fill, dtype)
            return base.at[row, col].set(x, mode="drop")

        tenant = jnp.broadcast_to(jnp.asarray(tenant, jnp.int32), (n,))
        state, _, done2d = self.read_array(
            state, flash,
            scat(lba, 0, jnp.int32),
            scat(t_submit, 0.0, jnp.float32),
            scat(valid, False, bool),
            with_data=False,
            tenant=scat(tenant, 0, jnp.int32),
        )
        done = jnp.where(
            valid, done2d[row, jnp.clip(col, 0, n - 1)], 0.0
        )
        data = flash[jnp.where(valid, lba, 0)]
        return state, data, done

    def write_replicated(
        self,
        state: ClientState,    # stacked array state (M devices)
        flash: jax.Array,
        data: jax.Array,       # (N, block_words) blocks to persist
        lba: jax.Array,        # (N,) i32 — any N
        t_submit: "jax.Array | float" = 0.0,   # () or (N,) f32
        valid: jax.Array | None = None,
        replicas: int = 2,
        tenant: "jax.Array | int" = 0,   # () or (N,) i32 QoS class
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Replica-write fan-out over an M-drive array.

        The durability dual of ``read_replicated``: block b's R replicas
        live on drives ``(b + r) % M`` (chained declustering), and a
        write must land on *all* of them, so every request fans out to
        its full candidate set — no routing choice — and its completion
        time is the **max** over the R per-replica completions (the
        write is durable only once the slowest replica has programmed).
        Each drive prices its share of the fan-out through its own
        pipeline (wire, lock, chips, GC); the functional scatter into
        the shared block store lands once per request, not R times.
        Returns (state', flash', done (N,)) in request order. Reads of
        any replica then see the block via ``read_replicated``.
        """
        m = jax.tree.leaves(state.dev)[0].shape[0]
        if not 1 <= replicas <= m:
            raise ValueError(
                f"replicas={replicas} must be in [1, M={m}] — a block "
                "cannot have more replicas than the array has drives"
            )
        n = lba.shape[0]
        r = replicas
        lba = lba.astype(jnp.int32)
        if valid is None:
            valid = jnp.ones((n,), bool)
        t_submit = jnp.broadcast_to(jnp.asarray(t_submit, jnp.float32), (n,))
        tenant = jnp.broadcast_to(jnp.asarray(tenant, jnp.int32), (n,))

        # (N, R) candidate drives, flattened request-major so each
        # drive's slots fill in request order. Within one request the R
        # candidates are distinct (R <= M), so no drive sees a request
        # twice and per-drive occupancy is <= N — an (M, N) grid holds
        # the whole fan-out.
        cand = (
            lba[:, None] + jnp.arange(r, dtype=jnp.int32)[None, :]
        ) % m                                            # (N, R)
        valid_rep = jnp.repeat(valid, r)                 # (N*R,)
        drive = jnp.where(valid_rep, cand.reshape(-1), jnp.int32(m))
        rank = segment_rank(drive)
        row = jnp.clip(drive, 0, m - 1)
        col = jnp.where(valid_rep, rank, n * r)

        def scat(x, fill, dtype):
            base = jnp.full((m, n), fill, dtype)
            return base.at[row, col].set(x, mode="drop")

        ops2d = StorageOps(
            opcode=jnp.full((m, n), OP_WRITE, jnp.int32),
            lba=scat(jnp.repeat(lba, r), 0, jnp.int32),
            t_submit=scat(jnp.repeat(t_submit, r), 0.0, jnp.float32),
            tenant=scat(jnp.repeat(tenant, r), 0, jnp.int32),
            valid=scat(valid_rep, False, bool),
        )
        state, _, _, done2d = self.submit_array(state, flash, ops2d)
        done_rep = done2d[row, jnp.clip(col, 0, n - 1)].reshape(n, r)
        done = jnp.where(valid, jnp.max(done_rep, axis=1), 0.0)
        # One functional store per request — replica fan-out is a
        # device-time phenomenon; the shared block store holds one copy.
        dst = jnp.where(valid, lba, flash.shape[0])
        flash = flash.at[dst].set(data, mode="drop")
        return state, flash, done
