"""Accelerator-initiated storage client (virtual time).

Applications (the SSD-backed KV tier, the vector-search case study) do not
need the full SQ-ring machinery — they issue *batched* block reads and
writes and need (a) the data moved, functionally, and (b) faithful
virtual-time completion times under a configured device model.
``StorageClient`` provides exactly that: each ``read``/``write`` models
GPU-initiated submission across the configured service units and returns
per-request completion times plus the moved blocks.

All cost modeling lives in the unified ``DevicePipeline`` (device.py) — the
same stages the closed-loop engine runs — so the client and the engine
provably price I/O identically: ``read``/``write`` are ``fetch_direct``
(stage 1, ring-less variant) followed by the shared ``process`` (stages
2-4; writes pick up flash program latency, GC back-pressure, and mapping
misses from stage 4). The client carries no cost formulas of its own.

``read_array``/``write_array``/``read_striped`` extend the same program to
an M-drive array: the per-device pipeline is ``vmap``-ed over a leading
device axis, so one jit program prices the whole array (paper-title
100-MIOPS regime at M x 40-MIOPS drives).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.device import (
    DevicePipeline,
    DeviceState,
    init_array_state,
    make_direct_batch,
)
from repro.core.types import (
    OP_WRITE,
    EngineConfig,
    PlatformModel,
    SSDConfig,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClientState:
    """Virtual-time device state carried across application steps."""

    dev: DeviceState

    @staticmethod
    def init(ssd: SSDConfig, num_units: int,
             workers_per_unit: int = 1) -> "ClientState":
        """Manual-shape constructor (escape hatch). Prefer
        ``StorageClient.init_state``, which derives unit/worker counts from
        the same EngineConfig the pipeline prices with — passing counts
        that disagree with the config silently prices a different device.
        """
        return ClientState(
            dev=DeviceState.init(ssd, num_units, workers_per_unit)
        )



@dataclasses.dataclass(frozen=True)
class StorageClient:
    ssd: SSDConfig
    cfg: EngineConfig
    plat: PlatformModel = PlatformModel()

    @property
    def pipeline(self) -> DevicePipeline:
        return DevicePipeline(self.cfg, self.ssd, self.plat)

    def init_state(self) -> ClientState:
        """Fresh state with unit/worker shapes derived from ``cfg`` — the
        exact shapes ``engine_round`` prices with (parity-safe for every
        frontend/datapath combination)."""
        return ClientState(dev=self.pipeline.init_state())

    def init_array_state(self, num_devices: int) -> ClientState:
        """Fresh stacked state for an M-drive array, cfg-derived shapes."""
        return ClientState(
            dev=init_array_state(self.pipeline, num_devices)
        )

    def read(
        self,
        state: ClientState,
        flash: jax.Array,      # (num_blocks, block_words)
        lba: jax.Array,        # (N,) i32 block addresses
        t_submit: jax.Array,   # () or (N,) f32 virtual submission time(s)
        valid: jax.Array | None = None,
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Issue N block reads at ``t_submit``.

        Returns (state', data (N, block_words), completion_times (N,)).
        """
        batch = make_direct_batch(lba, t_submit, valid)
        dev, res = self.pipeline.submit(state.dev, batch)
        data = flash[jnp.where(batch.valid, batch.lba, 0)]
        return ClientState(dev=dev), data, res.done

    def write(
        self,
        state: ClientState,
        flash: jax.Array,      # (num_blocks, block_words)
        data: jax.Array,       # (N, block_words) blocks to persist
        lba: jax.Array,        # (N,) i32 destination block addresses
        t_submit: jax.Array,   # () or (N,) f32 virtual submission time(s)
        valid: jax.Array | None = None,
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Issue N block writes at ``t_submit``.

        Priced by the identical pipeline as ``read`` — the OP_WRITE opcode
        routes stage 4 to flash programs (and GC once the free pool
        drains), so sustained writes are honestly slower than reads.
        Returns (state', flash' with the blocks scattered in,
        completion_times (N,)). If the batch writes the same LBA more
        than once, which copy lands is unspecified (XLA scatter with
        duplicate indices) — dedupe before submitting when that matters.
        """
        n = lba.shape[0]
        batch = make_direct_batch(
            lba, t_submit, valid, opcode=jnp.full((n,), OP_WRITE, jnp.int32)
        )
        dev, res = self.pipeline.submit(state.dev, batch)
        dst = jnp.where(batch.valid, batch.lba, flash.shape[0])
        flash = flash.at[dst].set(data, mode="drop")
        return ClientState(dev=dev), flash, res.done

    def read_array(
        self,
        state: ClientState,    # stacked: every leaf has a leading (M,) axis
        flash: jax.Array,      # (num_blocks, block_words) — shared store
        lba: jax.Array,        # (M, N) i32 per-device block addresses
        t_submit: jax.Array,   # scalar, (M,), or (M, N) f32
        valid: jax.Array | None = None,   # (M, N) bool
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Per-device batched reads over an M-drive array, one vmap."""
        m, n = lba.shape
        t_submit = jnp.asarray(t_submit, jnp.float32)
        if t_submit.ndim == 1:
            t_submit = t_submit[:, None]
        t_submit = jnp.broadcast_to(t_submit, (m, n))
        if valid is None:
            valid = jnp.ones((m, n), bool)

        def one(dev, lba_d, t_d, valid_d):
            batch = make_direct_batch(lba_d, t_d, valid_d)
            dev, res = self.pipeline.submit(dev, batch)
            return dev, res.done

        dev, done = jax.vmap(one)(state.dev, lba, t_submit, valid)
        data = flash[jnp.where(valid, lba, 0)]
        return ClientState(dev=dev), data, done

    def write_array(
        self,
        state: ClientState,    # stacked: every leaf has a leading (M,) axis
        flash: jax.Array,      # (num_blocks, block_words) — shared store
        data: jax.Array,       # (M, N, block_words) per-device payloads
        lba: jax.Array,        # (M, N) i32 per-device block addresses
        t_submit: jax.Array,   # scalar, (M,), or (M, N) f32
        valid: jax.Array | None = None,   # (M, N) bool
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Per-device batched writes over an M-drive array, one vmap.

        Virtual-time pricing is per drive (each device's pipeline carries
        its own chips/GC state); the functional scatter lands in the
        shared block store afterwards. If multiple rows (within or across
        devices) target the same LBA, which copy lands is unspecified
        (XLA scatter with duplicate indices) — partition the address
        space across drives when that matters.
        """
        m, n = lba.shape
        t_submit = jnp.asarray(t_submit, jnp.float32)
        if t_submit.ndim == 1:
            t_submit = t_submit[:, None]
        t_submit = jnp.broadcast_to(t_submit, (m, n))
        if valid is None:
            valid = jnp.ones((m, n), bool)
        op = jnp.full((n,), OP_WRITE, jnp.int32)

        def one(dev, lba_d, t_d, valid_d):
            batch = make_direct_batch(lba_d, t_d, valid_d, opcode=op)
            dev, res = self.pipeline.submit(dev, batch)
            return dev, res.done

        dev, done = jax.vmap(one)(state.dev, lba, t_submit, valid)
        dst = jnp.where(valid, lba, flash.shape[0]).reshape(-1)
        flash = flash.at[dst].set(
            data.reshape((m * n,) + data.shape[2:]), mode="drop"
        )
        return ClientState(dev=dev), flash, done

    def read_striped(
        self,
        state: ClientState,    # stacked array state (M devices)
        flash: jax.Array,
        lba: jax.Array,        # (N,) i32, N % M == 0
        t_submit: jax.Array,   # () or (N,) f32
        valid: jax.Array | None = None,
    ) -> Tuple[ClientState, jax.Array, jax.Array]:
        """Stripe a flat read batch round-robin over the array's M drives.

        Request i goes to drive ``i % M`` (fixed interleaved placement).
        Returns results in the original request order.
        """
        m = jax.tree.leaves(state.dev)[0].shape[0]
        n = lba.shape[0]
        if n % m != 0:
            raise ValueError(
                f"batch of {n} requests must be divisible by M={m} drives"
            )
        if valid is None:
            valid = jnp.ones((n,), bool)
        t_submit = jnp.broadcast_to(jnp.asarray(t_submit, jnp.float32), (n,))

        # (N,) -> (M, N//M): request i = stripe (i % M, i // M).
        def to_dev(x):
            return x.reshape(n // m, m).T

        def from_dev(x):
            return jnp.swapaxes(x, 0, 1).reshape((n,) + x.shape[2:])

        state, data, done = self.read_array(
            state, flash, to_dev(lba), to_dev(t_submit), to_dev(valid)
        )
        return state, from_dev(data), from_dev(done)
