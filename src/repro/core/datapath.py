"""Backend data path: functional block copies + worker/DSA cost model.

Functional emulation: the flash address space is an HBM-resident array of
blocks; a read gathers ``flash[lba] -> bufs[buf_id]``, a write scatters the
reverse. On TPU the gather runs as the ``block_gather`` Pallas kernel (the
DSA analogue: a batch of copy descriptors per grid step, double-buffered
DMA); on CPU / in tests the jnp reference path is used.

Virtual-time model: the *baseline* backend charges each request the
map/unmap software overhead plus a small sequential CPU copy (paper Fig. 4),
serialized per worker lane. The *DSA* backend charges batched descriptor
issue plus pipelined engine bandwidth, and shares the engine with
dispatcher-side fetching (paper Fig. 9 interference).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    counting_sort_plan,
    queueing_scan,
    segment_rank,
    stable_argsort,
)
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    RequestBatch,
    SSDConfig,
)


# ---------------------------------------------------------------------------
# Functional data movement.
# ---------------------------------------------------------------------------

def apply_reads(
    flash: jax.Array, bufs: jax.Array, batch: RequestBatch,
    use_pallas: bool = False,
) -> jax.Array:
    """Copy flash[lba] into bufs[buf_id] for valid read requests."""
    is_read = batch.valid & (batch.opcode == 0)
    src = jnp.where(is_read, batch.lba, 0)
    if use_pallas:
        from repro.kernels import ops as kops

        data = kops.block_gather(flash, src)
    else:
        data = flash[src]
    dst = jnp.where(is_read, batch.buf_id, bufs.shape[0])
    return bufs.at[dst].set(data, mode="drop")


def apply_writes(
    flash: jax.Array, bufs: jax.Array, batch: RequestBatch
) -> jax.Array:
    """Copy bufs[buf_id] into flash[lba] for valid write requests."""
    is_write = batch.valid & (batch.opcode == 1)
    src = jnp.where(is_write, batch.buf_id, 0)
    data = bufs[src]
    dst = jnp.where(is_write, batch.lba, flash.shape[0])
    return flash.at[dst].set(data, mode="drop")


# ---------------------------------------------------------------------------
# Virtual-time backend cost model.
# ---------------------------------------------------------------------------

def _bytes(batch: RequestBatch, ssd: SSDConfig) -> jax.Array:
    return (batch.nblocks * ssd.block_bytes).astype(jnp.float32)


def baseline_worker_times(
    work_time: jax.Array,       # (U, W) worker busy-until cursors
    map_time: jax.Array,        # ()  global map/unmap lock busy-until
    fetch_done: jax.Array,      # (N,) per request
    batch: RequestBatch,
    cfg: EngineConfig,
    plat: PlatformModel,
    ssd: SSDConfig,
    unit: jax.Array | None = None,   # (N,) non-decreasing service-unit ids
    unit_rank: jax.Array | None = None,  # (N,) within-unit rank (epoch plan)
    use_counting_sort: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """NVMeVirt backend: per-request map/unmap + CPU copy, W lanes per unit.

    memremap()/memunmap() mutate page tables under *global* kernel locks
    (paper §III-B: 94us per transfer at 32 threads ⇒ the 2.9us map cost is
    serialized across every worker, capping aggregate throughput at
    1/map_us ≈ 0.34 MIOPS). We model it as a single global queueing server
    feeding per-lane copy servers. Returns (work_time', map_time', ready).

    ``unit_rank`` (``DevicePipeline.process``'s epoch sort plan) supplies
    the within-unit ranks precomputed without a sort; omitted, they are
    recovered from ``unit`` via ``segment_rank`` (a full stable sort).
    ``use_counting_sort`` swaps the stable lane sort for the
    bit-identical counting-sort plan (the lane alphabet is u*w, small).
    """
    u, w = work_time.shape
    n = fetch_done.shape[0]
    pallas = cfg.resolve_pallas_segscan(ssd, plat)
    txn, bw = _p2p(cfg, plat)
    idx = jnp.arange(n, dtype=jnp.int32)
    if unit is None:
        unit = idx // (n // u)
        rank_in_unit = idx % (n // u)
    elif unit_rank is not None:
        rank_in_unit = unit_rank
    else:
        rank_in_unit = segment_rank(unit)

    # --- global map/unmap serialization (requests in dispatch order).
    map_cost = jnp.where(batch.valid, jnp.float32(plat.per_req_map_us), 0.0)
    heads0 = jnp.zeros((n,), bool).at[0].set(True, mode="drop")
    seed0 = jnp.broadcast_to(map_time, (n,))
    mapped = queueing_scan(
        fetch_done, map_cost, heads0, seed0, use_pallas=pallas
    )
    new_map = jnp.maximum(jnp.max(mapped), map_time)

    # --- per-lane p2p copy after mapping.
    cost = txn + _bytes(batch, ssd) / bw
    cost = jnp.where(batch.valid, cost, 0.0)
    lane = unit * w + (rank_in_unit % w)            # global lane id
    if use_counting_sort:
        plan = counting_sort_plan(lane, u * w)
        order, heads = plan.order, plan.heads
    else:
        order = stable_argsort(lane)
        heads = jnp.concatenate(
            [jnp.ones((1,), bool), lane[order][1:] != lane[order][:-1]]
        )
    seed = work_time.reshape(-1)[lane[order]]
    busy = queueing_scan(
        mapped[order], cost[order], heads, seed, use_pallas=pallas
    )
    ready = jnp.zeros_like(busy).at[order].set(busy, mode="drop")

    new_work = jax.ops.segment_max(
        busy, lane[order], num_segments=u * w
    )
    new_work = jnp.maximum(new_work, work_time.reshape(-1)).reshape(u, w)
    return new_work, new_map, jnp.where(batch.valid, ready, 0.0)


def dsa_worker_times(
    dsa_time: jax.Array,        # (U,) DSA-engine busy-until cursors
    fetch_done: jax.Array,      # (N,)
    batch: RequestBatch,
    cfg: EngineConfig,
    plat: PlatformModel,
    ssd: SSDConfig,
    dsa_batch_size: int = 16,
    unit: jax.Array | None = None,   # (N,) non-decreasing service-unit ids
) -> Tuple[jax.Array, jax.Array]:
    """SwarmIO backend: batched async DSA offload (paper §IV-C).

    CPU-side issue cost is amortized per batch descriptor; the DSA engine is
    a pipelined single server per unit at ``dsa_bytes_per_us``. No map/unmap
    (DSA operates on PAs). Returns (dsa_time', ready).
    """
    u = dsa_time.shape[0]
    n = fetch_done.shape[0]
    # Issue: one batch descriptor per `dsa_batch_size` requests.
    issue = plat.dsa_desc_issue_us + plat.dsa_batch_setup_us / dsa_batch_size
    ready_in = fetch_done + issue
    # Engine: pipelined copies, service time = bytes/bw (+ tiny per-desc).
    cost = _bytes(batch, ssd) / plat.dsa_bytes_per_us + 0.01
    cost = jnp.where(batch.valid, cost, 0.0)

    if unit is None:
        unit = jnp.arange(n, dtype=jnp.int32) // (n // u)
    heads = jnp.concatenate([jnp.ones((1,), bool), unit[1:] != unit[:-1]])
    seed = dsa_time[unit]
    busy = queueing_scan(ready_in, cost, heads, seed)

    new_dsa = jax.ops.segment_max(busy, unit, num_segments=u)
    new_dsa = jnp.maximum(new_dsa, dsa_time)
    return new_dsa, jnp.where(batch.valid, busy, 0.0)


def _p2p(cfg: EngineConfig, plat: PlatformModel):
    if cfg.transport == "p2p":
        return plat.txn_base_us, plat.link_bytes_per_us
    return plat.host_txn_base_us, plat.host_bytes_per_us
