"""The unified, layered device pipeline (single source of truth for cost).

Every consumer of the emulated SSD — the closed-loop engine and the
application-facing ``StorageClient`` — prices I/O through the same
stages over one ``DeviceState`` pytree:

    stage 0  page cache          GPU-side set-associative cache filters
                                 hits *before* SQ submission (cache.py;
                                 applied by the consumers, not here)
    stage 1  frontend fetch      how/when posted SQ entries become visible
                                 to a service unit (ring fetch, distributed
                                 or centralized — frontend.py)
    stage 2  timing model        target completion times under the global
                                 lock (aggregated / per-request, global /
                                 local scope — timing.py)
    stage 3  data path           when the emulated transfer lands (batched
                                 DSA offload or baseline worker threads —
                                 datapath.py)
    stage 4  flash backend       channel/chip occupancy for writes, greedy
                                 GC, and cached-mapping-table misses —
                                 surcharges the simple timing model omits
                                 (flash.py; exact no-op for all-hit
                                 read-only traffic)
    stage 5  CQ completion path  every completion is *posted* to the CQ
                                 paired with its SQ and *reaped* by the
                                 GPU consumer — coalescing, doorbell
                                 serialization, poll cost (qp.py; exact
                                 no-op under the neutral QPConfig)

For *remote* drives (``EngineConfig.fabric.remote``) two fabric hops
wrap the target-side stages (fabric.py): fetched SQEs plus write
payloads cross the TX link before stage 2, and completions plus read
payloads cross the RX link back before stage 5 — MTU-batched wire
transactions on per-link serialization cursors, plus half-RTT
propagation each way. With a finite ``switch_bytes_per_us`` the frames
additionally serialize through the shared switch/initiator-NIC port
(fan-out before the TX link, incast after the RX link) at the lane's
fair share of the aggregate roof, and with ``qos_weights`` configured
every shared hop serves tenants in weighted-fair order
(``RequestBatch.tenant``). Local drives (the default) skip all hops,
so the pipeline reproduces the fabric-less code path bit-exactly.

``DevicePipeline.process`` composes stages 2-5 for a fetched
``RequestBatch``: it threads the ``CQRings`` through and returns per-
request (arrival, target, ready, flash_done, done, reaped), where
``reaped`` — not ``done`` — is what consumers observe. Both the engine
and the client run ``frontend.fetch_{distributed,centralized}`` over the
same SQ rings and then call the identical ``process``; the queue-pair
layer is symmetric end to end. A multi-drive array is the same program
``vmap``-ed over a leading device axis (see
``engine.simulate(num_devices=...)`` and ``StorageClient.read_striped``).

Stage 2 consumes the batch as an admission ``Epoch`` (epoch.py): the
post-fabric-TX ready times, tenant ids, validity, unit ids, and the
row-layout promise travel as one struct, and ``EngineConfig.lock_order``
decides how service units acquire the global timing lock over it —
``"program"`` (default, bit-exact with every earlier PR) serializes
units in loop index order; ``"ready_time"`` grants the lock in order of
each unit's batch ready time and dispatches the timing model in the
same acquisition order (whole unit blocks permute; within a unit
program order always holds, and stages 3-5 keep the program row layout
— their resources are per-unit/per-die, so only the lock and the shared
timing state are admission-ordered).

The ring-less direct path (``_fetch_direct``/``_submit_direct``) is a
test-only shortcut for unit tests that probe stages 2-4 in isolation —
no production consumer uses it. The deprecated public aliases
``fetch_direct``/``submit_direct`` were removed in PR 9; go through
``StorageClient.submit`` (or the underscore names in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify

from repro.core import datapath, fabric as fabric_mod, frontend, qp, segops
from repro.core import timing
from repro.core.epoch import Epoch, admission_row_order, unit_ready_order
from repro.core.fabric import FabricState
from repro.core.flash import FlashState, flash_stage
from repro.core.qp import CQRings
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    RequestBatch,
    SSDConfig,
    TimingState,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceState:
    """All virtual-time emulator-side state for one emulated device."""

    tstate: TimingState    # shared timing model (busy_until + rr cursor)
    disp_time: jax.Array   # (U,) dispatcher busy-until cursors
    work_time: jax.Array   # (U, W) baseline worker lanes busy-until
    dsa_time: jax.Array    # (U,) DSA engine busy-until cursors
    lock_time: jax.Array   # ()  global timing-lock busy-until
    map_time: jax.Array    # ()  global map/unmap-lock busy-until
    flash: FlashState      # stage-4 flash-array state (chips, pages, GC)
    fabric: FabricState    # NIC/link cursors for remote drives (fabric.py)

    @staticmethod
    def init(ssd: SSDConfig, num_units: int, workers_per_unit: int = 1,
             num_tenants: int = 1) -> "DeviceState":
        return DeviceState(
            tstate=TimingState.init(ssd.n_instances),
            disp_time=jnp.zeros((num_units,), jnp.float32),
            work_time=jnp.zeros((num_units, workers_per_unit), jnp.float32),
            dsa_time=jnp.zeros((num_units,), jnp.float32),
            lock_time=jnp.float32(0),
            map_time=jnp.float32(0),
            flash=FlashState.init(ssd),
            fabric=FabricState.init(num_tenants),
        )

    @property
    def num_units(self) -> int:
        return self.disp_time.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PipelineResult:
    """Per-request virtual-time outcome of one pipeline pass (all (N,))."""

    arrival: jax.Array     # post-lock dispatch time seen by the timing model
    target: jax.Array      # timing-model completion (device fidelity)
    ready: jax.Array       # data-path completion (copy landed)
    flash_done: jax.Array  # flash-backend completion (programs/GC/misses)
    done: jax.Array        # max(target, ready, flash_done), 0 if invalid
    reaped: jax.Array      # when the consumer observed the completion via
                           # the fabric RX hop + CQ (== done for a local
                           # drive with no CQ threaded or a neutral QP)


def acquire_lock(
    lock_time: jax.Array,
    epoch: Epoch,
    num_units: int,
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[jax.Array, jax.Array, jax.Array | None]:
    """Serialize service units on the global timing-model lock.

    Returns ``(lock_time', lock_done (U,), unit_order)``. Cost =
    per-request (baseline) or per-batch (aggregated). Local timing scope
    has no shared lock at all: the "grant" is each unit's own batch
    ready time and ``unit_order`` is ``None``.

    ``cfg.lock_order`` picks the acquisition order:

      * ``"program"`` — units acquire in index order once their batch is
        ready (``unit_order=None``; the scan below runs on the unordered
        arrays, so the code path is byte-identical to every pre-PR-9
        release — the bit-exactness contract);
      * ``"ready_time"`` — units acquire in order of their batch ready
        time (ties by unit index, a stable sort): the ``(ready, unit)``
        keys permute the scan inputs, the grants unsort back to unit
        index order, and ``unit_order`` (the (U,) acquisition
        permutation) is returned so the caller can dispatch the timing
        model in the same order. When ready times are monotone in
        program order the permutation is the identity and both orders
        produce bit-identical grants.
    """
    if cfg.timing_scope == "local":
        return lock_time, epoch.unit_ready(num_units), None
    n_valid_u = epoch.unit_counts(num_units)
    batch_ready = epoch.unit_ready(num_units)
    if cfg.mode == "per_request":
        cost = n_valid_u.astype(jnp.float32) * plat.lock_per_req_us
    else:
        cost = jnp.where(n_valid_u > 0, plat.lock_per_batch_us, 0.0)

    # repro-lint: pinned-expr lock-scan
    def step(t, x):
        ready, c = x
        done = jnp.maximum(t, ready) + c
        return done, done

    if cfg.lock_order == "ready_time":
        unit_order = unit_ready_order(batch_ready)
        lock_end, granted = jax.lax.scan(
            step, lock_time, (batch_ready[unit_order], cost[unit_order])
        )
        lock_done = jnp.zeros_like(granted).at[unit_order].set(
            granted, mode="drop"
        )
        return lock_end, lock_done, unit_order
    lock_end, lock_done = jax.lax.scan(step, lock_time, (batch_ready, cost))
    return lock_end, lock_done, None
    # repro-lint: end-pinned-expr


def _sanitize_checks(
    cfg: EngineConfig,
    prev: DeviceState,
    new: DeviceState,
    batch: RequestBatch,
    res: PipelineResult,
    dispatch_order: jax.Array | None,
    cq_counts: jax.Array | None,
) -> None:
    """The ``EngineConfig.sanitize`` checkify assertions (PR 10).

    Pure observation — no data-path op changes — so a sanitized run's
    state stays bit-exact with the default run. These guard the failure
    modes JAX makes *silent*: an OOB ring index clamps/drops instead of
    erroring (corrupting CQ permutations), a broken admission or
    compaction permutation double-prices some rows and drops others,
    and flash/fabric accounting underflow shows up only as impossible
    virtual times rounds later. Callers must functionalize with
    ``checkify.checkify`` before jit (``engine.make_runner(...,
    sanitize=True)`` does); a plain jit trace with sanitize on raises
    at trace time by design — the flag must never be silently inert.
    """
    valid = batch.valid

    def rows_ok(pred: jax.Array) -> jax.Array:
        return jnp.all(jnp.where(valid, pred, True))

    # -- ring scatter/gather indices in bounds ---------------------------
    checkify.check(
        rows_ok((batch.sq_id >= 0) & (batch.sq_id < cfg.num_sqs)),
        "sanitize: valid row carries an SQ id outside [0, num_sqs) — "
        "the CQ scatter would silently drop its completion",
    )
    checkify.check(
        rows_ok((batch.slot >= 0) & (batch.slot < cfg.sq_depth)),
        "sanitize: valid row carries a ring slot outside [0, sq_depth)",
    )

    # -- completion times monotone non-negative --------------------------
    checkify.check(
        rows_ok(res.arrival >= 0.0),
        "sanitize: negative post-lock arrival time on a valid row",
    )
    checkify.check(
        rows_ok(res.target >= res.arrival),
        "sanitize: timing-model completion precedes its arrival",
    )
    checkify.check(
        rows_ok(res.ready >= res.arrival),
        "sanitize: data-path completion precedes its arrival",
    )
    checkify.check(
        rows_ok(res.flash_done >= 0.0),
        "sanitize: negative flash-backend completion time",
    )
    checkify.check(
        rows_ok(res.reaped >= res.done),
        "sanitize: CQ reap time precedes the wire completion it reaps",
    )
    checkify.check(
        jnp.all(new.disp_time >= prev.disp_time)
        & (new.lock_time >= prev.lock_time),
        "sanitize: a dispatcher/lock busy-until cursor moved backwards",
    )

    # -- valid-mask conservation across permutations ---------------------
    n = valid.shape[0]
    nv = jnp.sum(valid.astype(jnp.int32))
    if dispatch_order is not None:
        hits = jnp.zeros((n,), jnp.int32).at[dispatch_order].add(
            1, mode="drop"
        )
        checkify.check(
            jnp.all(hits == 1),
            "sanitize: admission dispatch_order is not a permutation — "
            "some rows would be double-priced and others dropped",
        )
        checkify.check(
            jnp.sum(valid[dispatch_order].astype(jnp.int32)) == nv,
            "sanitize: valid-mask not conserved through the admission "
            "permutation",
        )
    if cfg.use_compaction:
        plan = segops.compact_epoch(valid)
        hits = jnp.zeros((n,), jnp.int32).at[plan.pos].add(1, mode="drop")
        checkify.check(
            jnp.all(hits == 1) & (plan.n_valid == nv),
            "sanitize: epoch compaction does not conserve the valid "
            "mask (pos is not a permutation or n_valid drifted)",
        )
    if cq_counts is not None:
        checkify.check(
            jnp.sum(cq_counts.astype(jnp.int32)) == nv,
            "sanitize: per-CQ valid counts do not sum to the epoch's "
            "valid count",
        )

    # -- flash page accounting and fabric cursors ------------------------
    checkify.check(
        (new.flash.free_pages >= 0.0) & (new.flash.valid_pages >= 0.0),
        "sanitize: flash page accounting went negative (free or live "
        "page underflow — GC cannot keep up or double-counted)",
    )
    checkify.check(
        jnp.all(new.flash.chip_busy >= prev.flash.chip_busy),
        "sanitize: a flash die busy-until cursor moved backwards",
    )
    checkify.check(
        jnp.all(new.fabric.tx_busy >= prev.fabric.tx_busy)
        & jnp.all(new.fabric.rx_busy >= prev.fabric.rx_busy)
        & jnp.all(new.fabric.switch_tx >= prev.fabric.switch_tx)
        & jnp.all(new.fabric.switch_rx >= prev.fabric.switch_rx),
        "sanitize: a fabric serialization cursor moved backwards",
    )


@dataclasses.dataclass(frozen=True)
class DevicePipeline:
    """Static composition of the three stages for one device model."""

    cfg: EngineConfig
    ssd: SSDConfig
    plat: PlatformModel

    @property
    def num_units(self) -> int:
        return self.cfg.num_units if self.cfg.frontend == "distributed" else 1

    def init_state(self) -> DeviceState:
        return DeviceState.init(
            self.ssd, self.num_units, self.cfg.workers_per_unit,
            self.cfg.fabric.num_tenants,
        )

    # -- stage 1 (ring variants live in frontend.py) -------------------------
    def _fetch_direct(
        self,
        state: DeviceState,
        t_submit: jax.Array,   # (N,) f32
        valid: jax.Array,      # (N,) bool
    ) -> Tuple[DeviceState, jax.Array, jax.Array]:
        """TEST-ONLY: fetch a directly submitted flat batch (no SQ rings).

        Production consumers (engine *and* client) submit through the SQ
        rings and fetch via ``frontend.fetch_{distributed,centralized}``;
        this ring-less shortcut exists so unit tests can probe stages
        2-4 without ring machinery. Returns (state', fetch_done (N,),
        unit (N,)).
        """
        fetch_done, disp_time, unit = frontend.direct_fetch_times(
            state.disp_time, t_submit, valid, self.cfg, self.plat
        )
        return (
            dataclasses.replace(state, disp_time=disp_time), fetch_done, unit
        )

    def init_cq(self) -> CQRings:
        """Fresh CQ rings shaped to mirror the configured SQ rings."""
        return CQRings.empty(self.cfg.num_sqs, self.cfg.sq_depth)

    # -- stages 2-5 ----------------------------------------------------------
    def process(
        self,
        state: DeviceState,
        batch: RequestBatch,
        fetch_done: jax.Array,  # (N,) per-row fetch completion times
        unit: jax.Array,        # (N,) i32 non-decreasing service-unit ids
        cq: CQRings | None = None,
        ring_layout: bool = False,
    ) -> Tuple[DeviceState, CQRings | None, PipelineResult]:
        """Timing model under the global lock, then the backend data path,
        then the flash-level backend (writes/GC/mapping misses), then the
        CQ completion path: every completion is posted to the CQ paired
        with its SQ (``batch.sq_id``) and reaped by the consumer —
        ``result.reaped`` is the consumer-observed completion time.

        ``cq=None`` (test-only) skips stage 5: ``reaped`` is the wire-
        returned completion with no CQ machinery on top.

        ``ring_layout=True`` promises the batch came from the SQ-ring
        gather (``frontend._gather_entries``): rows are SQ-major with
        exactly ``cfg.fetch_width`` rows per SQ and ``N // num_units``
        rows per unit, so the compaction path may replace segmented
        reductions with fixed-width block reductions. The engine and
        client set it; the test-only direct path (whose ``sq_id`` is all
        zero) must not."""
        cfg, ssd, plat = self.cfg, self.ssd, self.plat
        fab = cfg.fabric
        u = state.num_units
        valid = batch.valid
        tenant = batch.tenants if fab.num_tenants > 1 else None

        # -- epoch sort plan (wall-clock optimization, bit-exact). The
        # fetched batch is SQ-major, so the service-unit and CQ keys are
        # non-decreasing: their segment layouts need no sort at all, and
        # the time-major fabric/CQ sorts fuse into one lexicographic
        # pass. Virtual time is identical either way (parity-tested).
        # ``use_compaction`` (PR 8) layers the epoch-compacted forms on
        # top: block-wise CQ ranks/counts and unit reductions (ring
        # layout only), the dense round-robin timing matrix, the
        # counting-sorted flash layout, and fused ring scatters — all
        # bit-exact, pinned by full-run parity tests.
        use_plan = cfg.use_sort_plan
        compact = cfg.use_compaction
        blocky = compact and ring_layout
        pallas = cfg.resolve_pallas_segscan(ssd, plat)
        unit_rank = segops.presorted_plan(unit).rank if use_plan else None
        if blocky:
            cq_rank = segops.block_masked_rank(valid, cfg.fetch_width)
            cq_counts = segops.block_counts(valid, cfg.fetch_width)
        else:
            cq_rank = (
                segops.masked_presorted_rank(batch.sq_id, valid)
                if use_plan else None
            )
            cq_counts = None

        # -- stage 1.5: fabric TX hop (remote drives only). Fetched SQEs
        # (plus write payloads) cross the wire before the target-side
        # pipeline sees them — through the shared switch port first
        # (fan-out direction), then this drive's own link; local drives
        # skip the stage entirely.
        fab_tx, fab_rx = state.fabric.tx_busy, state.fabric.rx_busy
        sw_tx, sw_rx = state.fabric.switch_tx, state.fabric.switch_rx
        if fab.remote:
            tx_bytes = fabric_mod.tx_wire_bytes(batch, plat.sqe_bytes, ssd)
            if fab.switched:
                sw_tx, fetch_done = fabric_mod.switch_hop(
                    sw_tx, fetch_done, tx_bytes, valid, fab, tenant,
                    fused_sort=use_plan, use_pallas=pallas,
                )
            fab_tx, fetch_done = fabric_mod.fabric_hop(
                fab_tx, fetch_done, tx_bytes,
                valid, fab, fab.tx_bytes_per_us, tenant,
                fused_sort=use_plan, use_pallas=pallas,
            )

        # -- stage 2a: global timing-model lock over the admission epoch.
        # The post-TX ``fetch_done`` *defines* the epoch's ready times (a
        # remote unit's batch is not at the device until its last frame
        # lands); the epoch's per-unit reductions are reshapes under the
        # ring layout (fixed-width unit slabs — integer sums and f32
        # maxes, exact under any association) and segmented forms on the
        # direct path. ``cfg.lock_order`` decides acquisition order; see
        # ``acquire_lock``.
        epoch = Epoch.from_batch(
            batch, fetch_done, unit, "ring" if ring_layout else "direct"
        )
        n_valid_u = epoch.unit_counts(u)
        lock_time, lock_done, unit_order = acquire_lock(
            state.lock_time, epoch, u, cfg, plat
        )
        disp_time = jnp.maximum(state.disp_time, lock_done)
        epoch = epoch.admit(lock_done)
        arrival = epoch.arrival

        # -- stage 2b: target completion times. Under the ready-time lock
        # the shared timing state is updated in lock-acquisition order:
        # unit blocks dispatch as their units acquired the lock (within a
        # unit program order holds), via a pure gather/scatter row
        # permutation — the float expression tree inside timing.update is
        # the verbatim reference one either way.
        tbatch = dataclasses.replace(batch, arrival=arrival)
        dispatch_order = (
            admission_row_order(unit_order, epoch, u)
            if unit_order is not None else None
        )
        if cfg.timing_scope == "local":
            tstate, target = timing.local_scope_update(
                state.tstate, arrival, valid, ssd, u,
                use_compaction=compact,
            )
        else:
            tstate, target = timing.update(
                state.tstate, tbatch, ssd, cfg.mode, use_compaction=compact,
                dispatch_order=dispatch_order,
            )

        # -- stage 3: backend data transfer.
        if cfg.batched_datapath:
            # DSA engine also carried the fetch transfer (engine sharing /
            # interference, paper Fig. 9b): bump cursors by fetch bytes.
            # count * sqe_bytes == the segment_sum of the constant bit-
            # for-bit: every partial sum of equal integer-valued f32
            # terms below 2^24 is exact under any association.
            if blocky:
                fetch_bytes_u = n_valid_u.astype(jnp.float32) * jnp.float32(
                    plat.sqe_bytes
                )
            else:
                fetch_bytes_u = jax.ops.segment_sum(
                    jnp.where(valid, jnp.float32(plat.sqe_bytes), 0.0),
                    unit, num_segments=u,
                )
            dsa_time0 = state.dsa_time + fetch_bytes_u / plat.dsa_bytes_per_us
            dsa_time, ready = datapath.dsa_worker_times(
                dsa_time0, arrival, batch, cfg, plat, ssd, unit=unit
            )
            work_time, map_time = state.work_time, state.map_time
        else:
            work_time, map_time, ready = datapath.baseline_worker_times(
                state.work_time, state.map_time, arrival, batch, cfg, plat,
                ssd, unit=unit, unit_rank=unit_rank,
                use_counting_sort=compact,
            )
            dsa_time = state.dsa_time

        # -- stage 4: flash-level backend (writes, GC, mapping misses).
        if ssd.flash_backend:
            fstate, flash_done = flash_stage(
                state.flash, batch, arrival, target, ssd, use_pallas=pallas,
                use_counting_sort=compact,
                use_pallas_flash=cfg.use_pallas_flash,
            )
        else:
            fstate, flash_done = state.flash, jnp.where(valid, arrival, 0.0)

        done = jnp.where(
            valid, jnp.maximum(jnp.maximum(target, ready), flash_done), 0.0
        )

        # -- stage 4.5: fabric RX hop. Completions (plus read payloads)
        # cross back to the initiator — over this drive's link first,
        # then the shared switch port all M return streams converge on
        # (incast) — before they reach its CQ.
        if fab.remote:
            rx_bytes = fabric_mod.rx_wire_bytes(batch, fab, ssd)
            fab_rx, wire_done = fabric_mod.fabric_hop(
                fab_rx, done, rx_bytes,
                valid, fab, fab.rx_bytes_per_us, tenant,
                fused_sort=use_plan, use_pallas=pallas,
            )
            if fab.switched:
                sw_rx, wire_done = fabric_mod.switch_hop(
                    sw_rx, wire_done, rx_bytes, valid, fab, tenant,
                    fused_sort=use_plan, use_pallas=pallas,
                )
            wire_done = jnp.where(valid, wire_done, 0.0)
        else:
            wire_done = done

        new_state = DeviceState(
            tstate=tstate, disp_time=disp_time, work_time=work_time,
            dsa_time=dsa_time, lock_time=lock_time, map_time=map_time,
            flash=fstate,
            fabric=FabricState(
                tx_busy=fab_tx, rx_busy=fab_rx,
                switch_tx=sw_tx, switch_rx=sw_rx,
            ),
        )

        # -- stage 5: post to the CQ and reap (queue-pair layer).
        if cq is None:
            reaped = wire_done
        else:
            cq, reaped = qp.post_and_reap(
                cq, batch.sq_id, wire_done, batch.req_id, valid, cfg.qp,
                posted_rank=cq_rank, fused_sort=use_plan, use_pallas=pallas,
                posted_counts=cq_counts, fused_scatter=compact,
                use_pallas_reap=cfg.use_pallas_reap,
            )
        res = PipelineResult(
            arrival=arrival, target=target, ready=ready,
            flash_done=flash_done, done=done, reaped=reaped,
        )
        if cfg.sanitize:
            _sanitize_checks(
                cfg, state, new_state, batch, res,
                dispatch_order, cq_counts,
            )
        return new_state, cq, res

    def _submit_direct(
        self,
        state: DeviceState,
        batch: RequestBatch,
    ) -> Tuple[DeviceState, PipelineResult]:
        """TEST-ONLY: _fetch_direct + process with no rings on either side.

        Op-agnostic — the batch's ``opcode`` decides read vs write pricing
        (stage 2/3 cost both identically; stage 4 charges programs, GC,
        and mapping misses where they apply). Production consumers go
        through the SQ/CQ rings instead (see ``StorageClient.submit``).
        """
        state, fetch_done, unit = self._fetch_direct(
            state, batch.arrival, batch.valid
        )
        state, _, res = self.process(state, batch, fetch_done, unit)
        return state, res


def init_array_state(init_fn, num_devices: int):
    """Stacked per-device state with a leading (M,) axis for vmap.

    ``init_fn(salt)`` builds one device's state pytree from its i32
    device index (salt-aware initializers — e.g. the engine's workload
    prefill — produce distinct per-drive streams; salt-oblivious ones —
    e.g. ``DevicePipeline.init_state`` — broadcast identically). This is
    the single device-layer stacking helper; ``engine.init_array_state``
    and ``StorageClient.init_array_state`` are thin adapters over it.
    """
    return jax.vmap(init_fn)(jnp.arange(num_devices, dtype=jnp.int32))


def make_direct_batch(
    lba: jax.Array,
    t_submit: jax.Array,
    valid: jax.Array | None = None,
    opcode: jax.Array | None = None,
    nblocks: jax.Array | None = None,
    tenant: jax.Array | None = None,
) -> RequestBatch:
    """RequestBatch for ring-less direct submission (test-only path)."""
    n = lba.shape[0]
    z = jnp.zeros((n,), jnp.int32)
    if valid is None:
        valid = jnp.ones((n,), bool)
    t_submit = jnp.broadcast_to(jnp.asarray(t_submit, jnp.float32), (n,))
    return RequestBatch(
        arrival=t_submit,
        sq_id=z, slot=z,
        opcode=z if opcode is None else opcode,
        lba=lba.astype(jnp.int32),
        nblocks=jnp.ones((n,), jnp.int32) if nblocks is None else nblocks,
        buf_id=z,
        req_id=jnp.arange(n, dtype=jnp.int32),
        valid=valid,
        tenant=z if tenant is None else tenant,
    )
