"""Closed-loop SwarmIO-JAX emulation engine.

One engine "round" mirrors a service-unit iteration in the paper (Fig. 6):

  1. dispatchers fetch newly visible SQ entries     (frontend.py)
  2. the timing model derives target completions    (timing.py) — guarded by
     the global lock, entered per-request (baseline) or per-batch (SwarmIO)
  3. the backend emulates the storage data transfer (datapath.py) — CPU
     worker threads with map/unmap (baseline) or batched async DSA offload
  4. the flash backend prices flash-level events    (flash.py) — write
     programs serializing per chip, greedy GC stealing die time, and
     cached-mapping-table misses (epoch-batched per round)
  5. completions are *posted* to the CQ paired with each request's SQ and
     *reaped* by the GPU consumer (qp.py) — coalesced doorbells, per-CQ
     doorbell serialization, and poll cost; the workload generator decides
     what each reaped slot submits next (closed-loop resubmit, open-loop
     arrival, or nothing for replays), and an optional stage-0 GPU page
     cache (cache.py) filters proposed reads that hit before they ever
     post an SQE

Stages 2-5 are the shared ``DevicePipeline`` (device.py) — the identical
code path ``StorageClient`` prices application I/O with. Two time domains
are tracked: *virtual time* (the emulated device's event time — fidelity
metrics: IOPS, latency vs. the modeled SSD) and the engine's own
*wall-clock throughput* (measured by benchmarks around ``run``).

A multi-drive array is the same jit program ``vmap``-ed over a leading
device axis: ``simulate(..., num_devices=M)`` emulates M independent drives
(per-device salted workload streams; fixed traces are striped row
``i % M -> drive i``) in one XLA computation —
``make_sharded_array_runner`` spreads the same stacked state over a real
JAX device mesh via ``shard_map``. With ``EngineConfig.fabric.remote``
each drive additionally sits behind its own NIC/link (fabric.py): SQEs
cross the wire before the target-side stages and completions cross back
before the CQ, so the array emulates a *disaggregated remote* all-flash
array.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import checkify

from repro.core import cache as cache_mod
from repro.core import datapath, frontend, segops
from repro.core.cache import CacheState
from repro.core.device import DevicePipeline, DeviceState
from repro.core.device import init_array_state as _stack_states
from repro.core.frontend import SQRings
from repro.core.qp import CQRings
from repro.core.types import (
    OP_READ,
    EngineConfig,
    PlatformModel,
    SSDConfig,
    WorkloadConfig,
)
from repro.workloads import Workload, as_workload

FAR = 3e38  # python float: jnp module constants leak into jaxprs

# Fixed log-spaced latency histogram: HIST_BUCKETS buckets spanning
# [HIST_LO_US, HIST_LO_US * 10**HIST_DECADES) microseconds; under- and
# overflow clamp to the edge buckets.
HIST_BUCKETS = 64
HIST_LO_US = 1.0
HIST_DECADES = 5.0


def latency_bucket(lat_us: jax.Array) -> jax.Array:
    """Histogram bucket index for an E2E latency (elementwise)."""
    lg = jnp.log10(jnp.maximum(lat_us, 1e-6)) - jnp.log10(
        jnp.float32(HIST_LO_US)
    )
    idx = jnp.clip(lg * (HIST_BUCKETS / HIST_DECADES), 0, HIST_BUCKETS - 1)
    return idx.astype(jnp.int32)


def hist_percentile(hist: jax.Array, q: float) -> jax.Array:
    """Approximate latency percentile from (possibly device-stacked) hist.

    Leading axes (e.g. a vmap device axis) are summed away, so array runs
    report the aggregate distribution. Returns the geometric midpoint of the
    first bucket where the CDF reaches ``q``.
    """
    h = hist.reshape(-1, HIST_BUCKETS).sum(axis=0)
    c = jnp.cumsum(h)
    idx = jnp.argmax(c >= q * c[-1])
    return jnp.float32(HIST_LO_US) * 10 ** (
        (idx.astype(jnp.float32) + 0.5) * HIST_DECADES / HIST_BUCKETS
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Metrics:
    completed: jax.Array      # f32 count (device completions + cache hits)
    fetched: jax.Array        # f32 count
    sum_e2e: jax.Array        # f32 us   (reap - submit, consumer-observed)
    sum_target: jax.Array     # f32 us   (timing-model latency)
    sum_proc: jax.Array       # f32 us   (copy-ready - dispatch)
    last_completion: jax.Array  # f32 us  max completion time seen
    first_submit: jax.Array   # f32 us   min submit time seen
    lat_hist: jax.Array       # (HIST_BUCKETS,) f32 E2E latency histogram
    cache_hits: jax.Array     # f32 count of stage-0 page-cache hits
    # Per-tenant (QoS class) device completions and E2E sums, shape (T,)
    # with T = max(fabric arbiter classes, workload classes) at init —
    # a single bucket by default. Stage-0 cache hits never reach the
    # device and are excluded.
    tenant_completed: jax.Array  # (T,) f32
    tenant_sum_e2e: jax.Array    # (T,) f32 us
    # Per-tenant E2E latency histograms, same log-spaced buckets as
    # lat_hist — the tail-latency view the ready-time lock study (fig29)
    # reads its per-class p99 and SLO-attainment numbers from.
    tenant_lat_hist: jax.Array   # (T, HIST_BUCKETS) f32

    @staticmethod
    def zero(num_tenants: int = 1) -> "Metrics":
        z = jnp.float32(0)
        # first_submit must be a *strong* f32: a python-float FAR would
        # make the fresh state weakly typed where a runner's output state
        # is strong — an aval mismatch that silently recompiled the jit
        # runner on the first benchmark rep (the rep-0 "compile" outlier
        # was mostly this second trace, not the warmup's).
        return Metrics(
            z, z, z, z, z, jnp.float32(0), jnp.float32(FAR),
            jnp.zeros((HIST_BUCKETS,), jnp.float32), z,
            jnp.zeros((num_tenants,), jnp.float32),
            jnp.zeros((num_tenants,), jnp.float32),
            jnp.zeros((num_tenants, HIST_BUCKETS), jnp.float32),
        )

    def iops(self) -> jax.Array:
        """Virtual-time sustained IOPS (requests per emulated second)."""
        span = jnp.maximum(self.last_completion - self.first_submit, 1e-6)
        return self.completed / span * 1e6

    def avg_e2e_us(self) -> jax.Array:
        return self.sum_e2e / jnp.maximum(self.completed, 1.0)

    def avg_target_us(self) -> jax.Array:
        return self.sum_target / jnp.maximum(self.completed, 1.0)

    def avg_proc_us(self) -> jax.Array:
        return self.sum_proc / jnp.maximum(self.completed, 1.0)

    def hit_rate(self) -> jax.Array:
        """Fraction of completed requests served by the stage-0 cache."""
        return self.cache_hits / jnp.maximum(self.completed, 1.0)

    def tenant_share(self) -> jax.Array:
        """(T,) fraction of device completions per tenant (sums to 1
        whenever anything completed). Leading device axes of an array
        run are summed away, so the shares are array-aggregate."""
        c = self.tenant_completed.reshape(
            -1, self.tenant_completed.shape[-1]
        ).sum(axis=0)
        return c / jnp.maximum(jnp.sum(c), 1.0)

    def tenant_avg_e2e_us(self) -> jax.Array:
        """(T,) mean consumer-observed latency per tenant."""
        c = self.tenant_completed.reshape(
            -1, self.tenant_completed.shape[-1]
        ).sum(axis=0)
        s = self.tenant_sum_e2e.reshape(
            -1, self.tenant_sum_e2e.shape[-1]
        ).sum(axis=0)
        return s / jnp.maximum(c, 1.0)

    def _pooled_tenant_hist(self) -> jax.Array:
        """(T, HIST_BUCKETS) with any leading device axes summed away."""
        t = self.tenant_completed.shape[-1]
        return self.tenant_lat_hist.reshape(-1, t, HIST_BUCKETS).sum(axis=0)

    def tenant_p99_us(self) -> jax.Array:
        """(T,) per-tenant p99 E2E latency (device completions; stage-0
        cache hits never reach the device and are excluded, matching
        ``tenant_completed``)."""
        return jax.vmap(lambda h: hist_percentile(h, 0.99))(
            self._pooled_tenant_hist()
        )

    def tenant_p50_us(self) -> jax.Array:
        """(T,) per-tenant median E2E latency (device completions)."""
        return jax.vmap(lambda h: hist_percentile(h, 0.50))(
            self._pooled_tenant_hist()
        )

    def slo_attainment(self, slo_us: float) -> jax.Array:
        """(T,) fraction of each tenant's device completions whose E2E
        latency landed at or below ``slo_us`` (histogram-resolution: a
        request counts as attained when its bucket's *lower* edge is
        under the SLO, so the estimate errs optimistic by at most one
        log-bucket). Tenants with no completions report 1.0 — an empty
        class has missed nothing."""
        h = self._pooled_tenant_hist()
        n = jnp.arange(HIST_BUCKETS, dtype=jnp.int32)
        ok = (n <= latency_bucket(jnp.float32(slo_us))).astype(jnp.float32)
        met = jnp.sum(h * ok[None, :], axis=1)
        tot = jnp.sum(h, axis=1)
        return jnp.where(tot > 0, met / jnp.maximum(tot, 1.0), 1.0)

    def p50_us(self) -> jax.Array:
        return hist_percentile(self.lat_hist, 0.50)

    def p95_us(self) -> jax.Array:
        return hist_percentile(self.lat_hist, 0.95)

    def p99_us(self) -> jax.Array:
        return hist_percentile(self.lat_hist, 0.99)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EngineState:
    rings: SQRings         # submission half of the queue pairs
    cq: CQRings            # completion half (SQ q pairs with CQ q)
    device: DeviceState    # the unified pipeline's virtual-time state
    cache: "CacheState | None"  # stage-0 GPU page cache (None = disabled)
    clock: jax.Array       # ()  virtual now
    flash: jax.Array       # (num_blocks, block_words) emulated flash
    bufs: jax.Array        # (num_bufs, block_words) I/O buffers
    req_counter: jax.Array  # i32 next request id
    salt: jax.Array        # i32 per-device workload salt (array emulation)
    last_submit: jax.Array  # (Q,) f32 newest submit time posted per SQ —
                            # the anchor open-loop arrival chains extend
    metrics: Metrics


# ---------------------------------------------------------------------------
# Workload initialization.
# ---------------------------------------------------------------------------

def init_state(
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: "Workload | WorkloadConfig",
    block_words: int = 16,
    salt: "jax.Array | int" = 0,
) -> EngineState:
    """Build rings pre-filled from the workload generator at t~0.

    ``salt`` differentiates the request streams of the devices in a vmapped
    multi-SSD array (pass the device index).
    """
    wl = as_workload(wl)
    if getattr(wl, "precondition_drive", False):
        # Steady-state generators start the flash array fully written.
        ssd = ssd.replace(preconditioned=True)
    q, dep = cfg.num_sqs, cfg.sq_depth
    rings = SQRings.empty(q, dep)

    pre = wl.prefill(cfg, ssd, salt)
    n_pre = pre.req_id.shape[0] * pre.req_id.shape[1]
    buf_id = (pre.req_id % cfg.num_bufs).astype(jnp.int32)
    rings = frontend.submit_grouped(
        rings, pre.submit, pre.opcode, pre.lba, pre.nblocks, buf_id,
        pre.req_id, pre.valid, tenant=pre.tenant,
        fused=cfg.use_compaction,
    )

    nb = ssd.num_blocks if cfg.emulate_data else 1
    nbuf = cfg.num_bufs if cfg.emulate_data else 1
    flash = (
        jnp.arange(nb, dtype=jnp.float32)[:, None]
        + jnp.arange(block_words, dtype=jnp.float32)[None, :] / block_words
    )
    bufs = jnp.zeros((nbuf, block_words), jnp.float32)
    pipe = DevicePipeline(cfg, ssd, PlatformModel())
    last_submit = jnp.max(
        jnp.where(pre.valid, pre.submit, 0.0), axis=1
    )
    return EngineState(
        rings=rings,
        cq=pipe.init_cq(),
        device=pipe.init_state(),
        cache=(
            CacheState.init(cfg.cache) if cfg.cache.enabled else None
        ),
        clock=jnp.float32(0),
        flash=flash,
        bufs=bufs,
        req_counter=jnp.int32(n_pre),
        salt=jnp.asarray(salt, jnp.int32),
        last_submit=last_submit,
        # Tenant metric buckets: enough for whichever layer defines more
        # classes — the fabric arbiter (qos_weights) or the workload
        # generator — so an unweighted (FIFO) baseline still reports
        # per-tenant shares/latency for a multi-tenant request stream.
        metrics=Metrics.zero(
            max(cfg.fabric.num_tenants, getattr(wl, "num_tenants", 1))
        ),
    )


# ---------------------------------------------------------------------------
# The engine round.
# ---------------------------------------------------------------------------

def engine_round(
    state: EngineState,
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: "Workload | WorkloadConfig",
    plat: PlatformModel,
) -> EngineState:
    wl = as_workload(wl)
    pipe = DevicePipeline(cfg, ssd, plat)
    q, f = cfg.num_sqs, cfg.fetch_width

    # -- 1. frontend fetch ---------------------------------------------------
    rings, disp_time, batch, fetch_done = frontend.fetch(
        state.rings, state.clock, state.device.disp_time, cfg, plat
    )
    submit_t = batch.arrival                       # provisional = submit time
    n = batch.valid.shape[0]
    unit = frontend.fetch_row_units(cfg)

    # -- 2-5. the unified device pipeline (timing + data path + QP) ----------
    dev = dataclasses.replace(state.device, disp_time=disp_time)
    # Fetched batches are SQ-major with fetch_width rows per SQ — the
    # ring-layout promise that lets compaction use block reductions.
    # process() wraps the batch in one admission epoch: the service
    # units of this round contend for the stage-2a lock in unit-loop
    # order, or by post-TX batch arrival under lock_order="ready_time".
    dev, cqr, res = pipe.process(
        dev, batch, fetch_done, unit, state.cq, ring_layout=True
    )

    # -- completion metrics: the consumer observes ``reaped`` (post-CQ) ------
    valid = batch.valid
    done = res.reaped
    e2e = jnp.where(valid, done - submit_t, 0.0)
    tgt_lat = jnp.where(valid, res.target - res.arrival, 0.0)
    proc = jnp.where(valid, res.ready - res.arrival, 0.0)
    nvalid = jnp.sum(valid.astype(jnp.float32))
    lat_hist = jax.ops.segment_sum(
        valid.astype(jnp.float32), latency_bucket(e2e),
        num_segments=HIST_BUCKETS,
    )
    # Per-tenant (QoS class) completion accounting: T is static (the
    # metrics' bucket count, fixed at init).
    n_ten = state.metrics.tenant_completed.shape[0]
    t_bucket = jnp.clip(batch.tenants, 0, n_ten - 1)
    tenant_completed = jax.ops.segment_sum(
        valid.astype(jnp.float32), t_bucket, num_segments=n_ten
    )
    tenant_sum_e2e = jax.ops.segment_sum(e2e, t_bucket, num_segments=n_ten)
    tenant_lat_hist = jnp.zeros((n_ten, HIST_BUCKETS), jnp.float32).at[
        t_bucket, latency_bucket(e2e)
    ].add(valid.astype(jnp.float32), mode="drop")

    # -- functional data movement --------------------------------------------
    flash, bufs = state.flash, state.bufs
    if cfg.emulate_data:
        bufs = datapath.apply_reads(flash, bufs, batch, cfg.use_pallas)
        flash = datapath.apply_writes(flash, bufs, batch)

    # -- workload-driven resubmission (stage-0 cache filters first) ----------
    # Rows are SQ-major (q, f); a row's tenant is its SQ's static class.
    tenant_rows = jnp.repeat(
        wl.tenant_of_sq(jnp.arange(q, dtype=jnp.int32), cfg, state.salt), f
    )
    new_req = state.req_counter + jnp.arange(n, dtype=jnp.int32)
    new_lba = wl.address(new_req, ssd, state.salt)
    new_op = wl.opcode(new_req, state.salt, tenant=tenant_rows)
    anchor = jnp.repeat(state.last_submit, f)
    resub_t, resub_valid = wl.next_submit(
        new_req, done, valid, anchor, cfg, ssd, state.salt
    )

    cstate = state.cache
    ccfg = cfg.cache
    hits_count = jnp.float32(0)
    hit_e2e = jnp.float32(0)
    hit_last = jnp.float32(0)
    hit_first = jnp.float32(FAR)
    hit_bucket = jnp.zeros((HIST_BUCKETS,), jnp.float32)
    ids_per_round = n
    if ccfg.enabled:
        # Fills: this round's completed device reads are now GPU-resident.
        cstate = cache_mod.insert(
            cstate, batch.lba, valid & (batch.opcode == OP_READ), ccfg
        )
        # Hit chase: a proposed read that hits completes at GPU-local
        # latency without ever posting an SQE, and the slot immediately
        # proposes its next request — up to ``chase`` hits per slot per
        # round; the survivor (first miss or chase-truncated request)
        # is what actually enters the rings.
        for k in range(ccfg.chase):
            hit, done_h = cache_mod.serve(
                cstate, new_lba,
                resub_valid & (new_op == OP_READ), resub_t, ccfg,
            )
            nh = jnp.sum(hit.astype(jnp.float32))
            hits_count = hits_count + nh
            hit_e2e = hit_e2e + nh * jnp.float32(ccfg.hit_us)
            hit_last = jnp.maximum(
                hit_last, jnp.max(jnp.where(hit, done_h, 0.0))
            )
            hit_first = jnp.minimum(
                hit_first, jnp.min(jnp.where(hit, resub_t, FAR))
            )
            hit_bucket = hit_bucket.at[
                latency_bucket(jnp.float32(ccfg.hit_us))
            ].add(nh, mode="drop")
            ids = (
                state.req_counter
                + n * (k + 1)
                + jnp.arange(n, dtype=jnp.int32)
            )
            s_lba = wl.address(ids, ssd, state.salt)
            s_op = wl.opcode(ids, state.salt, tenant=tenant_rows)
            s_t, s_valid = wl.next_submit(
                ids, done_h, hit, anchor, cfg, ssd, state.salt
            )
            new_lba = jnp.where(hit, s_lba, new_lba)
            new_op = jnp.where(hit, s_op, new_op)
            new_req = jnp.where(hit, ids, new_req)
            resub_t = jnp.where(hit, s_t, resub_t)
            resub_valid = jnp.where(hit, s_valid, resub_valid)
        ids_per_round = n * (ccfg.chase + 1)

    m = state.metrics
    metrics = Metrics(
        completed=m.completed + nvalid + hits_count,
        fetched=m.fetched + nvalid,
        sum_e2e=m.sum_e2e + jnp.sum(e2e) + hit_e2e,
        sum_target=m.sum_target + jnp.sum(tgt_lat),
        sum_proc=m.sum_proc + jnp.sum(proc),
        last_completion=jnp.maximum(
            jnp.maximum(
                m.last_completion, jnp.max(jnp.where(valid, done, 0.0))
            ),
            hit_last,
        ),
        first_submit=jnp.minimum(
            jnp.minimum(
                m.first_submit, jnp.min(jnp.where(valid, submit_t, FAR))
            ),
            hit_first,
        ),
        lat_hist=m.lat_hist + lat_hist + hit_bucket,
        cache_hits=m.cache_hits + hits_count,
        tenant_completed=m.tenant_completed + tenant_completed,
        tenant_sum_e2e=m.tenant_sum_e2e + tenant_sum_e2e,
        tenant_lat_hist=m.tenant_lat_hist + tenant_lat_hist,
    )

    resub_t = jnp.where(resub_valid, resub_t, FAR)
    last_submit = jnp.maximum(
        state.last_submit,
        jnp.max(
            jnp.where(resub_valid, resub_t, 0.0).reshape(q, f), axis=1
        ),
    )
    # Rows are SQ-major (q, f); sort each SQ's resubmissions by time.
    rt = resub_t.reshape(q, f)
    order = segops.stable_argsort(rt, axis=1)
    rows = jnp.arange(q, dtype=jnp.int32)[:, None]

    def pick(x):
        return x.reshape(q, f)[rows, order]

    rings = frontend.submit_grouped(
        rings,
        rt[rows, order],
        pick(new_op),
        pick(new_lba),
        pick(jnp.ones((n,), jnp.int32)),
        pick(batch.buf_id),
        pick(new_req),
        pick(resub_valid),
        tenant=pick(tenant_rows),
        fused=cfg.use_compaction,
    )

    # -- clock advance --------------------------------------------------------
    # Discrete-event step with a poll quantum: each round ingests the
    # submissions of a bounded virtual-time window (dispatchers poll
    # continuously in the real emulator; the quantum is our emulation
    # granularity — it bounds arrival-time rounding at <= quantum, far below
    # the >=50us device latencies modeled). Idle gaps are skipped by jumping
    # to the earliest pending submission.
    dpos = rings.head % rings.depth
    head_t = rings.submit_time[jnp.arange(q), dpos]
    head_t = jnp.where(rings.tail > rings.head, head_t, FAR)
    nxt = jnp.min(head_t)
    stepped = state.clock + jnp.float32(cfg.poll_quantum_us)
    clock = jnp.where(nxt < FAR, jnp.maximum(stepped, nxt), stepped)

    return EngineState(
        rings=rings, cq=cqr, device=dev, cache=cstate, clock=clock,
        flash=flash, bufs=bufs,
        req_counter=state.req_counter + jnp.int32(ids_per_round),
        salt=state.salt, last_submit=last_submit, metrics=metrics,
    )


def run(
    state: EngineState,
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: "Workload | WorkloadConfig",
    plat: PlatformModel,
    rounds: int,
) -> EngineState:
    """Run ``rounds`` engine rounds under jit (lax.scan over rounds)."""
    wl = as_workload(wl)

    def body(s, _):
        return engine_round(s, cfg, ssd, wl, plat), None

    out, _ = jax.lax.scan(body, state, None, length=rounds)
    return out


def unalias(state):
    """Deep-copy a pytree's leaves so no two share a device buffer.

    Freshly initialized states alias constants (JAX caches identical
    zero arrays), and XLA rejects donating the same buffer twice —
    run a donated runner's input through this once before the first
    call. Outputs of a jit call never alias, so reps can chain freely.
    """
    return jax.tree.map(lambda x: jnp.array(x, copy=True), state)


def _jit_runner(run_fn, donate: bool, sanitized: bool):
    """jit (and, when sanitized, checkify-functionalize) a runner body.

    ``checkify.check`` calls cannot trace under plain jit — they must be
    functionalized first, so the sanitized path wraps ``run_fn`` with
    ``checkify.checkify`` *inside* the jit boundary and the returned
    runner ``err.throw()``s on the host. The error pytree rides along as
    a regular output; the engine state itself is bit-exact with the
    unsanitized run (the checks only observe).
    """
    donate_argnums = (0,) if donate else ()
    if not sanitized:
        return jax.jit(run_fn, donate_argnums=donate_argnums)
    jitted = jax.jit(
        checkify.checkify(run_fn, errors=checkify.user_checks),
        donate_argnums=donate_argnums,
    )

    def runner(state):
        err, out = jitted(state)
        err.throw()
        return out

    return runner


def make_runner(
    cfg: EngineConfig, ssd: SSDConfig, wl, plat: PlatformModel,
    rounds: int, donate: bool = False, sanitize: bool = False,
):
    """jit-compiled engine runner with static configs baked in.

    ``donate=True`` donates the input ``EngineState``'s buffers to the
    call (``donate_argnums``), letting XLA reuse the ring/flash/buffer
    storage in place instead of copying it — the steady-state benchmark
    mode, where each rep feeds the previous rep's output back in. The
    caller must not reuse a donated input afterwards, hence default off.

    ``sanitize=True`` (or ``cfg.sanitize``) threads the checkify
    invariant assertions through every pipeline pass (see
    ``device._sanitize_checks``) and raises
    ``checkify.JaxRuntimeError`` from the returned runner on the first
    violated invariant. Virtual time is unchanged — the sanitized
    runner's output state is bit-exact with the default runner's
    (pinned by tests/test_sanitize.py).
    """
    wl = as_workload(wl)
    sanitized = sanitize or cfg.sanitize
    if sanitized:
        cfg = cfg.replace(sanitize=True)

    def _run(state: EngineState) -> EngineState:
        return run(state, cfg, ssd, wl, plat, rounds)

    return _jit_runner(_run, donate, sanitized)


def make_array_runner(
    cfg: EngineConfig, ssd: SSDConfig, wl, plat: PlatformModel,
    rounds: int, donate: bool = False, sanitize: bool = False,
):
    """jit-compiled M-drive array runner: ``run`` vmapped over the leading
    device axis of a stacked EngineState — one XLA program per array.
    ``donate``/``sanitize`` as in ``make_runner`` (checkify composes
    with the vmap: any drive's violated invariant throws)."""
    wl = as_workload(wl)
    sanitized = sanitize or cfg.sanitize
    if sanitized:
        cfg = cfg.replace(sanitize=True)

    def _run(states: EngineState) -> EngineState:
        return jax.vmap(
            lambda s: run(s, cfg, ssd, wl, plat, rounds)
        )(states)

    return _jit_runner(_run, donate, sanitized)


def make_sharded_array_runner(
    cfg: EngineConfig, ssd: SSDConfig, wl, plat: PlatformModel,
    rounds: int, mesh=None, axis_name: str = "dev",
):
    """M-drive array runner sharded across a JAX device mesh.

    Where ``make_array_runner`` vmaps the whole array onto one
    accelerator, this shards the stacked ``EngineState``'s leading
    device axis over a 1-D mesh via ``shard_map`` (the version-portable
    shim in ``distributed/sharding.py``) and vmaps each shard locally —
    so an M-drive array spreads over however many real devices the
    process holds, one XLA program per shard. M must be divisible by
    the mesh size. With a 1-device mesh this is semantically identical
    to ``make_array_runner`` (asserted bit-exactly in
    ``tests/test_fabric.py``).

    ``mesh`` defaults to all local devices on a ``(axis_name,)`` mesh.
    """
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.sharding import shard_map

    wl = as_workload(wl)
    if mesh is None:
        mesh = Mesh(np.asarray(jax.devices()), (axis_name,))

    def _shard(states: EngineState) -> EngineState:
        return jax.vmap(
            lambda s: run(s, cfg, ssd, wl, plat, rounds)
        )(states)

    sharded = jax.jit(shard_map(
        _shard, mesh, in_specs=P(axis_name), out_specs=P(axis_name)
    ))
    mesh_size = int(np.prod(mesh.devices.shape))

    def _run(states: EngineState) -> EngineState:
        m = jax.tree.leaves(states)[0].shape[0]
        if m % mesh_size != 0:
            raise ValueError(
                f"array of M={m} drives cannot shard over a mesh of "
                f"{mesh_size} devices — M must be divisible by the mesh "
                "size (pass a smaller mesh or resize the array)"
            )
        return sharded(states)

    return _run


def init_array_state(
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: "Workload | WorkloadConfig",
    num_devices: int,
    block_words: int = 16,
) -> EngineState:
    """Stacked EngineState for an M-drive array (device axis leading).

    Each drive gets a distinct workload salt, so salt-aware generators
    (closed loop, Poisson, Zipf) serve M independent request streams.
    Fixed-trace replays are striped via ``Workload.sharded``: drive d
    replays the rows whose time-sorted trace index i satisfies
    ``i % M == d`` (arrival times preserved), so array aggregates
    measure the one trace split M ways.
    """
    wl = as_workload(wl).sharded(num_devices)
    return _stack_states(
        lambda salt: init_state(cfg, ssd, wl, block_words, salt=salt),
        num_devices,
    )


def aggregate_iops(state: EngineState) -> jax.Array:
    """Array-aggregate virtual IOPS: sum of per-device sustained rates."""
    return jnp.sum(state.metrics.iops())


def simulate(
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: "Workload | WorkloadConfig",
    plat: PlatformModel | None = None,
    rounds: int = 64,
    block_words: int = 16,
    num_devices: int = 1,
) -> EngineState:
    """Convenience: init + run. Returns the final state.

    With ``num_devices=M > 1`` the returned EngineState has a leading (M,)
    device axis on every leaf (an emulated M-drive array, one jit program);
    aggregate throughput is ``aggregate_iops(state)`` and the histogram
    percentiles already pool across drives.
    """
    plat = plat or PlatformModel()
    if num_devices == 1:
        state = init_state(cfg, ssd, wl, block_words)
        return make_runner(cfg, ssd, wl, plat, rounds)(state)
    states = init_array_state(cfg, ssd, wl, num_devices, block_words)
    return make_array_runner(cfg, ssd, wl, plat, rounds)(states)
