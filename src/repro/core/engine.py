"""Closed-loop SwarmIO-JAX emulation engine.

One engine "round" mirrors a service-unit iteration in the paper (Fig. 6):

  1. dispatchers fetch newly visible SQ entries     (frontend.py)
  2. the timing model derives target completions    (timing.py) — guarded by
     the global lock, entered per-request (baseline) or per-batch (SwarmIO)
  3. the backend emulates the storage data transfer (datapath.py) — CPU
     worker threads with map/unmap (baseline) or batched async DSA offload
  4. completions post when BOTH the target time has elapsed AND the copy is
     done; the closed-loop client resubmits to the same SQ after think time

Two time domains are tracked: *virtual time* (the emulated device's event
time — fidelity metrics: IOPS, latency vs. the modeled SSD) and the engine's
own *wall-clock throughput* (measured by benchmarks around ``run``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import datapath, frontend, timing
from repro.core.frontend import SQRings
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    RequestBatch,
    SSDConfig,
    TimingState,
    WorkloadConfig,
)

FAR = 3e38  # python float: jnp module constants leak into jaxprs


def _hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-style integer hash (deterministic per-request randomness)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Metrics:
    completed: jax.Array      # f32 count
    fetched: jax.Array        # f32 count
    sum_e2e: jax.Array        # f32 us   (completion - submit)
    sum_target: jax.Array     # f32 us   (timing-model latency)
    sum_proc: jax.Array       # f32 us   (copy-ready - dispatch)
    last_completion: jax.Array  # f32 us  max completion time seen
    first_submit: jax.Array   # f32 us   min submit time seen

    @staticmethod
    def zero() -> "Metrics":
        z = jnp.float32(0)
        return Metrics(z, z, z, z, z, jnp.float32(0), FAR)

    def iops(self) -> jax.Array:
        """Virtual-time sustained IOPS (requests per emulated second)."""
        span = jnp.maximum(self.last_completion - self.first_submit, 1e-6)
        return self.completed / span * 1e6

    def avg_e2e_us(self) -> jax.Array:
        return self.sum_e2e / jnp.maximum(self.completed, 1.0)

    def avg_target_us(self) -> jax.Array:
        return self.sum_target / jnp.maximum(self.completed, 1.0)

    def avg_proc_us(self) -> jax.Array:
        return self.sum_proc / jnp.maximum(self.completed, 1.0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EngineState:
    rings: SQRings
    tstate: TimingState
    disp_time: jax.Array   # (U,) dispatcher busy-until
    work_time: jax.Array   # (U, W) baseline worker lanes busy-until
    dsa_time: jax.Array    # (U,) DSA engine busy-until
    lock_time: jax.Array   # ()  global timing-lock busy-until
    map_time: jax.Array    # ()  global map/unmap-lock busy-until
    clock: jax.Array       # ()  virtual now
    flash: jax.Array       # (num_blocks, block_words) emulated flash
    bufs: jax.Array        # (num_bufs, block_words) I/O buffers
    req_counter: jax.Array  # i32 next request id
    metrics: Metrics


# ---------------------------------------------------------------------------
# Workload initialization (fio / BaM closed loop).
# ---------------------------------------------------------------------------

def init_state(
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: WorkloadConfig,
    block_words: int = 16,
) -> EngineState:
    """Build rings pre-filled with ``io_depth`` entries per SQ at t~0."""
    q, dep = cfg.num_sqs, cfg.sq_depth
    if wl.io_depth > dep:
        raise ValueError("io_depth exceeds SQ depth")
    rings = SQRings.empty(q, dep)

    d = wl.io_depth
    req_id = (
        jnp.arange(q, dtype=jnp.int32)[:, None] * d
        + jnp.arange(d, dtype=jnp.int32)[None, :]
    )
    h = _hash_u32(req_id)
    lba = (h % jnp.uint32(ssd.num_blocks)).astype(jnp.int32)
    opcode = (
        (_hash_u32(req_id + 7919) % jnp.uint32(1000)).astype(jnp.float32)
        >= wl.read_frac * 1000
    ).astype(jnp.int32)
    # Stagger submissions by a few ns to define a total order at t≈0.
    submit = (
        jnp.arange(d, dtype=jnp.float32)[None, :] * 1e-3
        + jnp.arange(q, dtype=jnp.float32)[:, None] * 1e-5
    )
    buf_id = (req_id % cfg.num_bufs).astype(jnp.int32)
    valid = jnp.ones((q, d), bool)
    rings = frontend.submit_grouped(
        rings, submit, opcode, lba, jnp.ones_like(lba), buf_id, req_id, valid
    )

    nb = ssd.num_blocks if cfg.emulate_data else 1
    nbuf = cfg.num_bufs if cfg.emulate_data else 1
    flash = (
        jnp.arange(nb, dtype=jnp.float32)[:, None]
        + jnp.arange(block_words, dtype=jnp.float32)[None, :] / block_words
    )
    bufs = jnp.zeros((nbuf, block_words), jnp.float32)
    u = cfg.num_units if cfg.frontend == "distributed" else 1
    return EngineState(
        rings=rings,
        tstate=TimingState.init(ssd.n_instances),
        disp_time=jnp.zeros((u,), jnp.float32),
        work_time=jnp.zeros((u, cfg.workers_per_unit), jnp.float32),
        dsa_time=jnp.zeros((u,), jnp.float32),
        lock_time=jnp.float32(0),
        map_time=jnp.float32(0),
        clock=jnp.float32(0),
        flash=flash,
        bufs=bufs,
        req_counter=jnp.int32(q * d),
        metrics=Metrics.zero(),
    )


# ---------------------------------------------------------------------------
# The engine round.
# ---------------------------------------------------------------------------

def _lock_pass(
    lock_time: jax.Array,
    batch_ready: jax.Array,   # (U,) time each unit's batch is ready
    n_valid_u: jax.Array,     # (U,) valid requests per unit
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[jax.Array, jax.Array]:
    """Serialize dispatchers on the global timing-model lock.

    Returns (lock_time', lock_done (U,)). Units acquire in index order after
    their batch is ready. Cost = per-request (baseline) or per-batch
    (aggregated). Local timing scope has no shared lock at all.
    """
    if cfg.timing_scope == "local":
        return lock_time, batch_ready
    if cfg.mode == "per_request":
        cost = n_valid_u.astype(jnp.float32) * plat.lock_per_req_us
    else:
        cost = jnp.where(n_valid_u > 0, plat.lock_per_batch_us, 0.0)

    def step(t, x):
        ready, c = x
        done = jnp.maximum(t, ready) + c
        return done, done

    lock_end, lock_done = jax.lax.scan(step, lock_time, (batch_ready, cost))
    return lock_end, lock_done


def engine_round(
    state: EngineState,
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: WorkloadConfig,
    plat: PlatformModel,
) -> EngineState:
    q, f = cfg.num_sqs, cfg.fetch_width
    u = state.disp_time.shape[0]
    per_unit_rows = q * f // u

    # -- 1. frontend fetch ---------------------------------------------------
    if cfg.frontend == "distributed":
        rings, disp_time, batch, fetch_done = frontend.fetch_distributed(
            state.rings, state.clock, state.disp_time, cfg, plat
        )
    else:
        rings, disp_time, batch, fetch_done = frontend.fetch_centralized(
            state.rings, state.clock, state.disp_time, cfg, plat
        )
    submit_t = batch.arrival                       # provisional = submit time
    n = batch.valid.shape[0]
    row_unit = jnp.arange(n, dtype=jnp.int32) // per_unit_rows

    # -- 2. timing model under the global lock -------------------------------
    n_valid_u = jax.ops.segment_sum(
        batch.valid.astype(jnp.int32), row_unit, num_segments=u
    )
    batch_ready = jax.ops.segment_max(
        jnp.where(batch.valid, fetch_done, 0.0), row_unit, num_segments=u
    )
    lock_time, lock_done = _lock_pass(
        state.lock_time, batch_ready, n_valid_u, cfg, plat
    )
    disp_time = jnp.maximum(disp_time, lock_done)

    arrival = jnp.maximum(fetch_done, lock_done[row_unit])
    tbatch = dataclasses.replace(batch, arrival=arrival)
    if cfg.timing_scope == "local":
        # Paper's rejected design: per-unit state, 1/U capacity each.
        k_u = max(ssd.n_instances // u, 1)
        local_ssd = ssd.replace(t_max_iops=ssd.t_max_iops / u, n_instances=k_u)
        bu = state.tstate.busy_until.reshape(u, -1)
        rr_u = jnp.broadcast_to(state.tstate.rr, (u,))

        def per_unit(bu_u, rr_1, val_u, arr_u):
            inst_u, rr_2 = timing.assign_rr(rr_1, val_u, k_u)
            comp, nb = timing.aggregated_batch_times(
                bu_u, arr_u, inst_u, val_u, local_ssd
            )
            return nb, rr_2, comp

        nb, rr_new, comp = jax.vmap(per_unit)(
            bu, rr_u, batch.valid.reshape(u, -1), arrival.reshape(u, -1)
        )
        tstate = TimingState(nb.reshape(-1), rr_new[0])
        target = comp.reshape(-1)
    else:
        tstate, target = timing.update(state.tstate, tbatch, ssd, cfg.mode)

    # -- 3. backend data transfer --------------------------------------------
    if cfg.batched_datapath:
        # DSA engine also carried the fetch transfer (engine sharing /
        # interference, paper Fig. 9b): bump cursors by fetch bytes.
        fetch_bytes_u = jax.ops.segment_sum(
            jnp.where(batch.valid, jnp.float32(plat.sqe_bytes), 0.0),
            row_unit, num_segments=u,
        )
        dsa_time0 = state.dsa_time + fetch_bytes_u / plat.dsa_bytes_per_us
        dsa_time, ready = datapath.dsa_worker_times(
            dsa_time0, arrival, batch, cfg, plat, ssd
        )
        work_time = state.work_time
        map_time = state.map_time
    else:
        work_time, map_time, ready = datapath.baseline_worker_times(
            state.work_time, state.map_time, arrival, batch, cfg, plat, ssd
        )
        dsa_time = state.dsa_time

    # -- 4. completion --------------------------------------------------------
    done = jnp.maximum(target, ready)
    valid = batch.valid
    e2e = jnp.where(valid, done - submit_t, 0.0)
    tgt_lat = jnp.where(valid, target - arrival, 0.0)
    proc = jnp.where(valid, ready - arrival, 0.0)
    nvalid = jnp.sum(valid.astype(jnp.float32))
    m = state.metrics
    metrics = Metrics(
        completed=m.completed + nvalid,
        fetched=m.fetched + nvalid,
        sum_e2e=m.sum_e2e + jnp.sum(e2e),
        sum_target=m.sum_target + jnp.sum(tgt_lat),
        sum_proc=m.sum_proc + jnp.sum(proc),
        last_completion=jnp.maximum(
            m.last_completion, jnp.max(jnp.where(valid, done, 0.0))
        ),
        first_submit=jnp.minimum(
            m.first_submit, jnp.min(jnp.where(valid, submit_t, FAR))
        ),
    )

    # -- 5. functional data movement ------------------------------------------
    flash, bufs = state.flash, state.bufs
    if cfg.emulate_data:
        bufs = datapath.apply_reads(flash, bufs, batch, cfg.use_pallas)
        flash = datapath.apply_writes(flash, bufs, batch)

    # -- 6. closed-loop resubmission -------------------------------------------
    new_req = state.req_counter + jnp.arange(n, dtype=jnp.int32)
    h = _hash_u32(new_req)
    new_lba = (h % jnp.uint32(ssd.num_blocks)).astype(jnp.int32)
    new_op = (
        (_hash_u32(new_req + 7919) % jnp.uint32(1000)).astype(jnp.float32)
        >= wl.read_frac * 1000
    ).astype(jnp.int32)
    resub_t = jnp.where(valid, done + wl.resubmit_delay_us, FAR)
    # Rows are SQ-major (q, f); sort each SQ's resubmissions by time.
    rt = resub_t.reshape(q, f)
    order = jnp.argsort(rt, axis=1)
    rows = jnp.arange(q, dtype=jnp.int32)[:, None]

    def pick(x):
        return x.reshape(q, f)[rows, order]

    rings = frontend.submit_grouped(
        rings,
        rt[rows, order],
        pick(new_op),
        pick(new_lba),
        pick(jnp.ones((n,), jnp.int32)),
        pick(batch.buf_id),
        pick(new_req),
        pick(valid),
    )

    # -- 7. clock advance ------------------------------------------------------
    # Discrete-event step with a poll quantum: each round ingests the
    # submissions of a bounded virtual-time window (dispatchers poll
    # continuously in the real emulator; the quantum is our emulation
    # granularity — it bounds arrival-time rounding at <= quantum, far below
    # the >=50us device latencies modeled). Idle gaps are skipped by jumping
    # to the earliest pending submission.
    dpos = rings.head % rings.depth
    head_t = rings.submit_time[jnp.arange(q), dpos]
    head_t = jnp.where(rings.tail > rings.head, head_t, FAR)
    nxt = jnp.min(head_t)
    stepped = state.clock + jnp.float32(cfg.poll_quantum_us)
    clock = jnp.where(nxt < FAR, jnp.maximum(stepped, nxt), stepped)

    return EngineState(
        rings=rings, tstate=tstate, disp_time=disp_time,
        work_time=work_time, dsa_time=dsa_time, lock_time=lock_time,
        map_time=map_time, clock=clock, flash=flash, bufs=bufs,
        req_counter=state.req_counter + jnp.int32(n), metrics=metrics,
    )


def run(
    state: EngineState,
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: WorkloadConfig,
    plat: PlatformModel,
    rounds: int,
) -> EngineState:
    """Run ``rounds`` engine rounds under jit (lax.scan over rounds)."""

    def body(s, _):
        return engine_round(s, cfg, ssd, wl, plat), None

    out, _ = jax.lax.scan(body, state, None, length=rounds)
    return out


def make_runner(
    cfg: EngineConfig, ssd: SSDConfig, wl: WorkloadConfig, plat: PlatformModel,
    rounds: int,
):
    """jit-compiled engine runner with static configs baked in."""

    @jax.jit
    def _run(state: EngineState) -> EngineState:
        return run(state, cfg, ssd, wl, plat, rounds)

    return _run


def simulate(
    cfg: EngineConfig,
    ssd: SSDConfig,
    wl: WorkloadConfig,
    plat: PlatformModel | None = None,
    rounds: int = 64,
    block_words: int = 16,
) -> EngineState:
    """Convenience: init + run. Returns the final state."""
    plat = plat or PlatformModel()
    state = init_state(cfg, ssd, wl, block_words)
    return make_runner(cfg, ssd, wl, plat, rounds)(state)
