"""The admission epoch: one fetched batch's view of the timing core.

Every ``DevicePipeline.process`` pass handles one *epoch* — the set of
requests a round of dispatchers fetched together. Before PR 9 the
stage-2 inputs (arrival cursor, post-fabric ready times, tenant ids,
validity mask, unit ids, and the ring-layout promise) traveled as loose
positional arguments, and the global timing lock could only serialize
units in their loop index order because nothing carried "when did this
unit's batch actually become ready" as first-class state. ``Epoch``
packages exactly that tuple — ``(arrival, ready, tenant, valid, unit,
layout)`` — so the lock (``device.acquire_lock``) and the timing model
(``timing.update(dispatch_order=...)``) can consume admission order as
data:

  * ``ready``   — per-row device-arrival times *after* the fabric TX hop
                  (the hop defines ready times: a remote unit's batch is
                  not at the device until its last frame lands);
  * ``arrival`` — the evolving per-row time cursor: equals ``ready`` at
                  admission, then ``max(ready, lock grant)`` once the
                  unit holds the lock (``admit``);
  * ``layout``  — "ring" promises the SQ-major fixed-width row blocks of
                  ``frontend._gather_entries`` (units are contiguous
                  ``N // U`` row slabs), which turns the per-unit
                  reductions and the admission-order row permutation into
                  reshapes/gathers; "direct" falls back to segmented
                  forms on the non-decreasing ``unit`` key.

Ordering helpers (``unit_ready_order`` / ``admission_row_order``) build
the lock-acquisition permutation from ``(ready, unit)`` keys: a stable
sort, so ties (and the all-equal single-tenant case) preserve program
order — the property the ``lock_order="ready_time"`` equivalence tests
pin. The permutation moves *whole unit blocks* and never any float
arithmetic, so the timing model's expression tree stays verbatim (the
PR-8 FMA-contraction lesson: gathers are bit-exact, reformulations are
not).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.segops import stable_argsort
from repro.core.types import RequestBatch


@dataclasses.dataclass(frozen=True)
class Epoch:
    """One fetched batch's admission state (struct of (N,) arrays).

    ``layout`` is static metadata ("ring" | "direct"), not a leaf —
    registered via explicit ``data_fields``/``meta_fields`` below.
    """

    arrival: jax.Array  # (N,) f32 evolving per-row time cursor
    ready: jax.Array    # (N,) f32 post-fabric-TX device arrival times
    tenant: jax.Array   # (N,) i32 QoS class per row
    valid: jax.Array    # (N,) bool
    unit: jax.Array     # (N,) i32 non-decreasing service-unit ids
    layout: str = "direct"   # "ring" | "direct" (static)

    @staticmethod
    def from_batch(
        batch: RequestBatch,
        ready: jax.Array,
        unit: jax.Array,
        layout: str,
    ) -> "Epoch":
        """Admission view of a fetched batch; ``ready`` is the post-TX
        fetch-done vector (== raw fetch times on a local drive)."""
        return Epoch(
            arrival=ready, ready=ready, tenant=batch.tenants,
            valid=batch.valid, unit=unit, layout=layout,
        )

    @property
    def capacity(self) -> int:
        return self.ready.shape[0]

    @property
    def is_ring(self) -> bool:
        return self.layout == "ring"

    def rows_per_unit(self, num_units: int) -> int:
        """Fixed block width of the ring layout's unit slabs."""
        return self.capacity // num_units

    # -- per-unit reductions (stage-2a inputs) -------------------------------
    def unit_counts(self, num_units: int) -> jax.Array:
        """(U,) valid-request count per unit (exact integer reduction)."""
        if self.is_ring:
            return jnp.sum(
                self.valid.reshape(num_units, -1).astype(jnp.int32), axis=1
            )
        return jax.ops.segment_sum(
            self.valid.astype(jnp.int32), self.unit, num_segments=num_units
        )

    def unit_ready(self, num_units: int) -> jax.Array:
        """(U,) batch ready time per unit: the max over its valid rows
        (a unit's batch enters the lock once its last frame has landed;
        empty units reduce to 0)."""
        masked = jnp.where(self.valid, self.ready, 0.0)
        if self.is_ring:
            return jnp.max(masked.reshape(num_units, -1), axis=1)
        return jax.ops.segment_max(
            masked, self.unit, num_segments=num_units
        )

    # -- lock-grant application ----------------------------------------------
    def admit(self, lock_done: jax.Array) -> "Epoch":
        """Advance the cursor to the lock grant: ``arrival = max(ready,
        lock_done[unit])`` — a row dispatches only once its unit holds
        the lock *and* its own frame has landed."""
        return dataclasses.replace(
            self, arrival=jnp.maximum(self.ready, lock_done[self.unit])
        )


jax.tree_util.register_dataclass(
    Epoch,
    data_fields=["arrival", "ready", "tenant", "valid", "unit"],
    meta_fields=["layout"],
)


def unit_ready_order(batch_ready: jax.Array) -> jax.Array:
    """(U,) lock-acquisition permutation: units by ``(ready, index)``.

    Stable sort, so equal ready times keep program order — with monotone
    ready times this is the identity and ``lock_order="ready_time"``
    degenerates to ``"program"`` bit-exactly (property-tested)."""
    return stable_argsort(batch_ready).astype(jnp.int32)


def admission_row_order(
    unit_order: jax.Array,   # (U,) i32 acquisition order (unit indices)
    epoch: Epoch,
    num_units: int,
) -> jax.Array:
    """(N,) row permutation dispatching unit *blocks* in lock order.

    Position j of the permuted batch holds the j-th row dispatched: unit
    blocks follow ``unit_order``, rows inside a block keep program order
    (within a unit nothing reorders — the lock is per unit). Pure index
    arithmetic under the ring layout's fixed-width slabs; a stable
    argsort of each row's acquisition rank otherwise. Either way the
    permutation is data movement only: gathering rows through it and
    scattering results back cannot perturb a single float (the
    bit-exactness contract ``timing.update(dispatch_order=...)`` relies
    on)."""
    if epoch.is_ring:
        w = epoch.rows_per_unit(num_units)
        return (
            unit_order[:, None] * jnp.int32(w)
            + jnp.arange(w, dtype=jnp.int32)[None, :]
        ).reshape(-1)
    lock_pos = jnp.zeros((num_units,), jnp.int32).at[unit_order].set(
        jnp.arange(num_units, dtype=jnp.int32), mode="drop"
    )
    return stable_argsort(lock_pos[epoch.unit]).astype(jnp.int32)
