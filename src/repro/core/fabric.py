"""Fabric/interconnect layer: the NIC/link hop to a remote drive.

Disaggregated all-flash arrays (GNStor-style GPU-native remote storage)
reach their drives over a network fabric, and at tens of MIOPS per drive
the *wire* — not the flash — is often the roof: a 512-byte read payload
plus a 16-byte CQE at 40 MIOPS is >21 GB/s of sustained return traffic
per drive. This module prices that hop as two per-direction single-server
links around the device pipeline:

  * **TX (initiator -> target)** — fetched SQEs (plus write payloads)
    cross the wire before the target-side timing model sees them;
  * **RX (target -> initiator)** — completions (plus read payloads)
    cross back before they are posted to the initiator-side CQ.

All accounting is epoch-batched in the same style as the CQ layer
(qp.py): one ``fabric_hop`` call prices a whole batch's frames in time
order, frames pack into MTU batches of ``mtu_batch`` per wire
transaction (flushed early once the oldest frame has waited
``mtu_timeout_us``), each transaction pays ``wire_txn_us`` of NIC setup
plus its bytes at the link bandwidth on a serialized per-link cursor,
and every direction adds half the configured RTT of propagation. The
cursor only advances when a frame actually occupies the link (cost > 0),
so a zero-cost wire — ``inf`` bandwidth, zero RTT/txn — is an *exact*
no-op even across epochs, and ``FabricConfig(remote=False)`` skips the
stage entirely (the PR-3 parity contract).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    NEG,
    queueing_scan,
    segmented_prefix_max,
    sort_by_segment,
)
from repro.core.types import OP_WRITE, FabricConfig, RequestBatch, SSDConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FabricState:
    """Per-drive link state (one remote drive = one link each way).

    An M-drive remote array vmaps the pipeline over a leading device
    axis, so the stacked state carries M independent link cursors — the
    per-link load signal replica reads balance against
    (``StorageClient.read_replicated``).
    """

    tx_busy: jax.Array  # () f32 initiator->target serialization cursor
    rx_busy: jax.Array  # () f32 target->initiator serialization cursor

    @staticmethod
    def init() -> "FabricState":
        return FabricState(
            tx_busy=jnp.float32(0),
            rx_busy=jnp.float32(0),
        )


def tx_wire_bytes(
    batch: RequestBatch, sqe_bytes: int, ssd: SSDConfig
) -> jax.Array:
    """Outbound bytes per frame: the SQE plus any write payload."""
    payload = jnp.where(
        batch.opcode == OP_WRITE,
        batch.nblocks.astype(jnp.float32) * jnp.float32(ssd.block_bytes),
        0.0,
    )
    return jnp.float32(sqe_bytes) + payload


def rx_wire_bytes(
    batch: RequestBatch, fab: FabricConfig, ssd: SSDConfig
) -> jax.Array:
    """Return bytes per frame: the CQE plus any read payload."""
    payload = jnp.where(
        batch.opcode != OP_WRITE,
        batch.nblocks.astype(jnp.float32) * jnp.float32(ssd.block_bytes),
        0.0,
    )
    return jnp.float32(fab.cqe_bytes) + payload


def fabric_hop(
    busy: jax.Array,  # () f32 this direction's link cursor
    t_ready: jax.Array,  # (N,) f32 frame-ready times (fetch_done / done)
    nbytes: jax.Array,  # (N,) f32 wire bytes per frame
    valid: jax.Array,  # (N,) bool
    fab: FabricConfig,
    bytes_per_us: float,
) -> Tuple[jax.Array, jax.Array]:
    """Price one epoch's frames over one link direction.

    Returns ``(busy', t_out)``: ``t_out[i]`` is when frame i's last byte
    lands on the far side (MTU flush -> serialized transmission ->
    half-RTT propagation). Invalid rows pass through untouched. Frames
    stream progressively: within a wire transaction each frame becomes
    visible once its own bytes have crossed, so a large MTU batch does
    not hold its first frame for the whole transfer.
    """
    # Time-sort, then segment valid frames ahead of invalid ones (the
    # qp.py layout: invalid rows form a trailing pseudo-segment whose
    # group stats never mix with real frames).
    key = jnp.where(valid, 0, 1)
    ord1 = jnp.argsort(t_ready, stable=True)
    ord2, heads, rank = sort_by_segment(key[ord1])
    order = ord1[ord2]
    s_t = t_ready[order]
    s_valid = valid[order]
    s_bytes = nbytes[order]

    # MTU batches: contiguous runs of mtu_batch frames. A batch ships
    # when it fills (last member's ready time) or its flush timer
    # expires (first member + mtu_timeout_us), whichever is earlier; a
    # frame completing after that flush ships at its own ready time (it
    # would have ridden the next transaction).
    gheads = heads | (rank % fab.mtu_batch == 0)
    tails = jnp.concatenate([gheads[1:], jnp.ones((1,), bool)])
    first = segmented_prefix_max(jnp.where(gheads, s_t, NEG), gheads)
    rev = slice(None, None, -1)
    full = segmented_prefix_max(
        jnp.where(tails, s_t, NEG)[rev], tails[rev]
    )[rev]
    bell = jnp.minimum(full, first + jnp.float32(fab.mtu_timeout_us))
    ready = jnp.maximum(s_t, bell)

    # Serialized transmission: per-transaction NIC setup at the batch
    # head, per-frame bytes at the link bandwidth, single-server queue
    # seeded from the link cursor.
    cost = jnp.where(s_valid, s_bytes / jnp.float32(bytes_per_us), 0.0)
    cost = cost + jnp.where(
        gheads & s_valid, jnp.float32(fab.wire_txn_us), 0.0
    )
    sent = queueing_scan(ready, cost, heads, busy)

    # The cursor advances only where a frame actually occupied the link:
    # a zero-cost wire imposes no serialization (exact no-op contract).
    busy = jnp.maximum(
        busy,
        jnp.max(jnp.where(s_valid & (cost > 0.0), sent, NEG)),
    )
    landed = sent + jnp.float32(0.5 * fab.rtt_us)
    t_out = jnp.zeros_like(t_ready).at[order].set(landed)
    return busy, jnp.where(valid, t_out, t_ready)
