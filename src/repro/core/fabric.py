"""Fabric/interconnect layer: the NIC/link hop to a remote drive.

Disaggregated all-flash arrays (GNStor-style GPU-native remote storage)
reach their drives over a network fabric, and at tens of MIOPS per drive
the *wire* — not the flash — is often the roof: a 512-byte read payload
plus a 16-byte CQE at 40 MIOPS is >21 GB/s of sustained return traffic
per drive. This module prices that hop as two per-direction single-server
links around the device pipeline:

  * **TX (initiator -> target)** — fetched SQEs (plus write payloads)
    cross the wire before the target-side timing model sees them;
  * **RX (target -> initiator)** — completions (plus read payloads)
    cross back before they are posted to the initiator-side CQ.

All accounting is epoch-batched in the same style as the CQ layer
(qp.py): one ``fabric_hop`` call prices a whole batch's frames in time
order, frames pack into MTU batches of ``mtu_batch`` per wire
transaction (flushed early once the oldest frame has waited
``mtu_timeout_us``), each transaction pays ``wire_txn_us`` of NIC setup
plus its bytes at the link bandwidth on a serialized per-link cursor,
and every direction adds half the configured RTT of propagation. A
frame that becomes ready only after its MTU batch has flushed ships as
its own late transaction: it pays ``wire_txn_us`` again (it cannot ride
a doorbell that already rang). Cursors only advance when a frame
actually occupies the link (cost > 0), so a zero-cost wire — ``inf``
bandwidth, zero RTT/txn — is an *exact* no-op even across epochs, and
``FabricConfig(remote=False)`` skips the stage entirely (the PR-3
parity contract).

Two shared-resource stages extend the per-drive links:

  * **Shared switch / initiator NIC** (``switch_hop``): the M per-drive
    links of a remote array converge on one switch port per direction
    (incast on RX, fan-out on TX). Each vmapped drive lane serializes
    its frames through a switch cursor at the fair per-link share
    ``switch_bytes_per_us / switch_fanin`` — the epoch-batched
    fair-share port model, exact for the symmetric saturated regime the
    roofline figures measure and an upper bound on per-lane bandwidth
    otherwise (an idle lane's share is not redistributed).
  * **Weighted-fair per-tenant QoS**: with more than one entry in
    ``qos_weights`` every shared resource runs one serialization cursor
    *per tenant class* in the fluid generalized-processor-sharing
    discretization: the tenants with traffic in an epoch split the
    resource in weight proportion (tenant k's frames serialize at
    ``w_k / sum(active w)`` of the bandwidth on k's own cursor), so a
    bulk tenant can no longer occupy the whole wire ahead of a latency
    tenant's small frames, saturated throughput shares track the
    configured weights, and a lone active tenant still gets the full
    bandwidth (work conservation at epoch granularity — a tenant idle
    for part of an epoch does not donate its share within it). MTU
    batches never mix tenants (NIC queues are per class). With a single
    class the cursor vector has one entry and the hop is bit-exact
    with the unweighted path.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    NEG,
    lex_sort_by_segment,
    queueing_scan,
    segmented_prefix_max,
    sort_by_segment,
    stable_argsort,
)
from repro.core.types import OP_WRITE, FabricConfig, RequestBatch, SSDConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FabricState:
    """Per-drive link state (one remote drive = one link each way).

    Every cursor is a ``(T,)`` vector with one entry per tenant class
    (``T = FabricConfig.num_tenants``, 1 unless QoS weights are
    configured): tenant k's frames serialize on entry k at k's
    weighted share of the resource. An M-drive remote array vmaps the
    pipeline over a leading device axis, so the stacked state carries
    M independent link cursors — the per-link load signal replica
    reads balance against (``StorageClient.read_replicated``).
    ``switch_tx``/``switch_rx`` are the lane's cursors on the *shared*
    switch port (each lane serializes at its fair share of the
    aggregate switch roof).
    """

    tx_busy: jax.Array  # (T,) f32 initiator->target serialization cursors
    rx_busy: jax.Array  # (T,) f32 target->initiator serialization cursors
    switch_tx: jax.Array  # (T,) f32 shared-switch cursors, TX direction
    switch_rx: jax.Array  # (T,) f32 shared-switch cursors, RX direction

    @staticmethod
    def init(num_tenants: int = 1) -> "FabricState":
        z = jnp.zeros((num_tenants,), jnp.float32)
        return FabricState(tx_busy=z, rx_busy=z, switch_tx=z, switch_rx=z)


def tx_wire_bytes(
    batch: RequestBatch, sqe_bytes: int, ssd: SSDConfig
) -> jax.Array:
    """Outbound bytes per frame: the SQE plus any write payload."""
    payload = jnp.where(
        batch.opcode == OP_WRITE,
        batch.nblocks.astype(jnp.float32) * jnp.float32(ssd.block_bytes),
        0.0,
    )
    return jnp.float32(sqe_bytes) + payload


def rx_wire_bytes(
    batch: RequestBatch, fab: FabricConfig, ssd: SSDConfig
) -> jax.Array:
    """Return bytes per frame: the CQE plus any read payload."""
    payload = jnp.where(
        batch.opcode != OP_WRITE,
        batch.nblocks.astype(jnp.float32) * jnp.float32(ssd.block_bytes),
        0.0,
    )
    return jnp.float32(fab.cqe_bytes) + payload


def _frame_layout(
    t_ready: jax.Array,
    valid: jax.Array,
    tenant: "jax.Array | None",
    fab: FabricConfig,
    fused_sort: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Canonical epoch layout shared by the link and switch hops.

    Frames sort by ready time, then segment by (tenant class, with
    invalid rows as a trailing pseudo-segment) — original time order
    preserved within each segment. With one tenant class this is
    exactly the validity layout of the unweighted path. Returns
    ``(order, heads, rank, key_clip)``: the permutation into the
    layout, segment heads and within-segment ranks there, and each
    row's clipped tenant id for cursor/weight gathers. ``fused_sort``
    swaps the two-sort composition for the bit-identical one-pass
    lexicographic sort (``segops.lex_sort_by_segment``).
    """
    t = fab.num_tenants
    if tenant is None or t == 1:
        cls = jnp.zeros_like(valid, jnp.int32)
    else:
        cls = jnp.clip(tenant, 0, t - 1)
    key = jnp.where(valid, cls, t)
    if fused_sort:
        order, heads, rank = lex_sort_by_segment(key, t_ready)
    else:
        ord1 = stable_argsort(t_ready)
        ord2, heads, rank = sort_by_segment(key[ord1])
        order = ord1[ord2]
    return order, heads, rank, jnp.clip(key[order], 0, t - 1)


def _gps_serve(
    busy: jax.Array,  # (T,) per-tenant cursors for this resource
    ready: jax.Array,  # (N,) f32 frame-ready times (epoch layout)
    cost: jax.Array,  # (N,) f32 full-bandwidth service cost per frame
    s_valid: jax.Array,  # (N,) bool
    heads: jax.Array,  # (N,) bool tenant-segment heads
    key_clip: jax.Array,  # (N,) i32 clipped tenant id per row
    fab: FabricConfig,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Serve one epoch on per-tenant cursors at weighted shares.

    The tenants with any valid frame in the epoch split the resource
    in weight proportion: tenant k's frames run the single-server
    recurrence on cursor k with costs inflated by ``sum(active w) /
    w_k`` (fluid GPS at epoch granularity). A lone active tenant pays
    plain cost (full bandwidth); with one configured class the factor
    is exactly 1.0 and the result is bit-identical to the unweighted
    scan. Returns ``(busy', sent)``; cursors only advance where a
    frame carried cost.
    """
    t = fab.num_tenants
    w = jnp.asarray(fab.qos_weights or (1.0,), jnp.float32)
    active = jnp.maximum(
        jax.ops.segment_max(
            s_valid.astype(jnp.float32), key_clip, num_segments=t
        ),
        0.0,
    )
    act_w = jnp.sum(w * active)
    act_w = jnp.where(act_w > 0.0, act_w, 1.0)
    eff = cost * (act_w / w[key_clip])
    sent = queueing_scan(
        ready, eff, heads, busy[key_clip], use_pallas=use_pallas
    )
    busy = jnp.maximum(
        busy,
        jax.ops.segment_max(
            jnp.where(s_valid & (cost > 0.0), sent, NEG),
            key_clip,
            num_segments=t,
        ),
    )
    return busy, sent


def fabric_hop(
    busy: jax.Array,  # (T,) f32 this direction's link cursor(s)
    t_ready: jax.Array,  # (N,) f32 frame-ready times (fetch_done / done)
    nbytes: jax.Array,  # (N,) f32 wire bytes per frame
    valid: jax.Array,  # (N,) bool
    fab: FabricConfig,
    bytes_per_us: float,
    tenant: "jax.Array | None" = None,  # (N,) i32 QoS class per frame
    fused_sort: bool = False,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Price one epoch's frames over one link direction.

    Returns ``(busy', t_out)``: ``t_out[i]`` is when frame i's last byte
    lands on the far side (MTU flush -> serialized transmission ->
    half-RTT propagation). Invalid rows pass through untouched. Frames
    stream progressively: within a wire transaction each frame becomes
    visible once its own bytes have crossed, so a large MTU batch does
    not hold its first frame for the whole transfer.
    """
    busy = jnp.atleast_1d(jnp.asarray(busy, jnp.float32))
    order, heads, rank, key_clip = _frame_layout(
        t_ready, valid, tenant, fab, fused_sort=fused_sort
    )
    s_t = t_ready[order]
    s_valid = valid[order]
    s_bytes = nbytes[order]

    # MTU batches: contiguous runs of mtu_batch frames within a tenant
    # segment (NIC queues never mix classes). A batch ships when it
    # fills (last member's ready time) or its flush timer expires
    # (first member + mtu_timeout_us), whichever is earlier; a frame
    # completing after that flush ships at its own ready time (it
    # would have ridden the next transaction).
    gheads = heads | (rank % fab.mtu_batch == 0)
    tails = jnp.concatenate([gheads[1:], jnp.ones((1,), bool)])
    first = segmented_prefix_max(jnp.where(gheads, s_t, NEG), gheads)
    rev = slice(None, None, -1)
    full = segmented_prefix_max(
        jnp.where(tails, s_t, NEG)[rev], tails[rev]
    )[rev]
    bell = jnp.minimum(full, first + jnp.float32(fab.mtu_timeout_us))
    ready = jnp.maximum(s_t, bell)

    # Serialized transmission: per-transaction NIC setup at the batch
    # head, per-frame bytes at the link bandwidth, single-server queue
    # per tenant cursor. A post-flush straggler missed its batch's
    # doorbell and ships as its own wire transaction, so it pays the
    # NIC setup again instead of riding for free.
    cost = jnp.where(s_valid, s_bytes / jnp.float32(bytes_per_us), 0.0)
    cost = cost + jnp.where(
        (gheads | (s_t > bell)) & s_valid, jnp.float32(fab.wire_txn_us), 0.0
    )
    busy, sent = _gps_serve(
        busy, ready, cost, s_valid, heads, key_clip, fab,
        use_pallas=use_pallas,
    )
    landed = sent + jnp.float32(0.5 * fab.rtt_us)
    t_out = jnp.zeros_like(t_ready).at[order].set(landed, mode="drop")
    return busy, jnp.where(valid, t_out, t_ready)


def switch_hop(
    busy: jax.Array,  # (T,) f32 this lane's shared-switch cursor(s)
    t_ready: jax.Array,  # (N,) f32 frame-ready times
    nbytes: jax.Array,  # (N,) f32 wire bytes per frame
    valid: jax.Array,  # (N,) bool
    fab: FabricConfig,
    tenant: "jax.Array | None" = None,  # (N,) i32 QoS class per frame
    fused_sort: bool = False,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Price one epoch's frames through the shared switch port.

    The incast stage: all M per-drive links of a remote array feed one
    switch/initiator-NIC port per direction, so each lane's frames
    additionally serialize at the fair per-link share
    ``switch_bytes_per_us / switch_fanin``. Frames are already framed
    by the link hop — no MTU re-batching, NIC setup, or propagation
    here, just bytes through the port share on carried per-tenant
    cursors (weighted GPS across tenants like every shared resource).
    A zero-cost switch (``inf`` roof) never advances the cursors.
    """
    busy = jnp.atleast_1d(jnp.asarray(busy, jnp.float32))
    share = fab.switch_share_bytes_per_us
    order, heads, _, key_clip = _frame_layout(
        t_ready, valid, tenant, fab, fused_sort=fused_sort
    )
    s_t = t_ready[order]
    s_valid = valid[order]

    cost = jnp.where(s_valid, nbytes[order] / jnp.float32(share), 0.0)
    busy, sent = _gps_serve(
        busy, s_t, cost, s_valid, heads, key_clip, fab,
        use_pallas=use_pallas,
    )
    t_out = jnp.zeros_like(t_ready).at[order].set(sent, mode="drop")
    return busy, jnp.where(valid, t_out, t_ready)
