"""Flash-level backend: channels/chips, writes, GC, mapping misses.

Pipeline stage 4 (device.py). The simple timing model (stage 2) already
prices the *calibrated read path* — ``sched_us``/``l_min_us`` encode the
device's sustained random-read behavior, flash parallelism included. What
it cannot express are the flash-level events an IOPS-optimized device
actually spends time on once writes and cold mapping state enter the
picture. This stage models exactly those surcharges over a
``C channels x W chips`` die array (SimpleSSD-style holistic modeling,
scoped to what changes completion times):

  * **writes** occupy their die for ``flash_program_us`` and serialize
    per chip (a program blocks the die, not the whole device);
  * **mapping misses** (cached-mapping-table misses, the KV-SSD line's
    dominant random-read cost) charge a translation-page read on the
    mapped die before the data read's device service can begin;
  * **garbage collection** runs greedily when the free-page pool drops
    below a watermark, stealing die time for victim migration + erase.

All accounting is *epoch-batched* in the spirit of SwarmIO's lazy timing
updates: one ``flash_stage`` call prices a whole fetched batch — requests
observe the die cursors as of epoch start, the batch's events advance
them once, and GC triggers at most once per epoch with its cost spread
across the dies. With ``mapping_hit_rate=1.0`` and no writes the stage is
an exact no-op (cursors never move, every surcharge is zero), so
read-only workloads reproduce the 3-stage pipeline bit-exactly — the
PR-1 parity contract, preserved through the queue-pair completion layer
(stage 5, qp.py) whose neutral default likewise adds zero time.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    NEG,
    counting_positions,
    hash_u32,
    queueing_scan,
    sort_by_segment,
    uniform01,
)
from repro.core.types import OP_WRITE, RequestBatch, SSDConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlashState:
    """Flash-array state for one emulated device (vmap-able over drives)."""

    chip_busy: jax.Array    # (C*W,) f32 per-die busy-until cursors
    free_pages: jax.Array   # () f32 free (erased) physical pages
    valid_pages: jax.Array  # () f32 physical pages holding live data
    io_seq: jax.Array       # () i32 ops priced so far (CMT-miss hash salt)
    prog_seq: jax.Array     # () i32 programs placed so far (rr write cursor)
    gc_count: jax.Array     # () f32 total GC invocations

    @staticmethod
    def init(ssd: SSDConfig) -> "FlashState":
        """Fresh or steady-state drive per ``ssd.preconditioned``.

        A preconditioned drive starts fully written (every logical page
        live), so its free pool is only the over-provisioned spare area
        and sustained writes hit the GC watermark almost immediately —
        the steady-state regime fresh-drive benchmarks overstate.
        """
        phys = jnp.float32(ssd.phys_pages)
        valid = jnp.float32(ssd.num_blocks if ssd.preconditioned else 0.0)
        return FlashState(
            chip_busy=jnp.zeros((ssd.num_chips,), jnp.float32),
            free_pages=phys - valid,
            valid_pages=valid,
            io_seq=jnp.int32(0),
            prog_seq=jnp.int32(0),
            gc_count=jnp.float32(0),
        )

    @property
    def num_chips(self) -> int:
        return self.chip_busy.shape[0]


def chip_of(lba: jax.Array, ssd: SSDConfig) -> jax.Array:
    """Map an LBA to its die (channel striping by address hash)."""
    h = (lba.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(ssd.num_chips)).astype(jnp.int32)


def mapping_miss(
    fstate: FlashState, batch: RequestBatch, ssd: SSDConfig
) -> jax.Array:
    """Which valid reads miss the cached mapping table this epoch.

    Counter-based: hashed from the request id, the accessed LBA, and the
    device's running op count, so the miss stream is deterministic and
    distinct across epochs — and diverges across vmapped array drives,
    whose salted workloads access different addresses even when their
    request-id streams coincide. ``mapping_hit_rate=1.0`` can never
    miss — ``uniform01`` is open at 1.0.
    """
    if ssd.mapping_hit_rate >= 1.0:
        # Static shortcut: ``uniform01`` is open at 1.0, so a fully
        # cached mapping table can never miss — skip the hash entirely.
        return jnp.zeros_like(batch.valid)
    is_read = batch.valid & (batch.opcode != OP_WRITE)
    h = hash_u32(
        batch.req_id.astype(jnp.uint32)
        + batch.lba.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
        + fstate.io_seq.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    )
    return is_read & (uniform01(h) >= jnp.float32(ssd.mapping_hit_rate))


def flash_stage(
    fstate: FlashState,
    batch: RequestBatch,
    arrival: jax.Array,   # (N,) f32 post-lock dispatch times
    target: jax.Array,    # (N,) f32 stage-2 timing-model completions
    ssd: SSDConfig,
    use_pallas: bool = False,
    use_counting_sort: bool = False,
    use_pallas_flash: bool = False,
) -> Tuple[FlashState, jax.Array]:
    """Price one epoch's flash-level events. Returns (state', flash_done).

    ``flash_done[i]`` is the earliest time request i's flash-side work can
    be complete; the pipeline takes ``max(target, ready, flash_done)``.
    Per row:

      * hit read    — no event; blocked only behind die work already
                      scheduled at epoch start (programs/GC on its die);
      * miss read   — a translation-page read queues on the die, then the
                      data read's device service (``target - arrival``)
                      restarts after it;
      * write       — a program queues on the die and completes there.

    Die cursors only ever move forward: events advance them via a
    per-chip queueing scan, GC adds non-negative stolen time.

    ``use_counting_sort`` (PR 8) swaps the stable die sort for the
    bit-identical ``segops.counting_positions`` layout (the die alphabet
    is small) plus one stacked scatter and a gather-side unsort — same
    permutation, same scan, same times. ``use_pallas_flash`` routes the
    whole contention pass (sort + scan + cursor max) through the
    ``kernels/ops`` sequential die-contention kernel; like the segscan
    kernel it is bit-exact on integer-valued timestamps (it folds the
    recurrence sequentially instead of re-associating the scan).
    """
    k = ssd.num_chips
    valid = batch.valid
    is_write = valid & (batch.opcode == OP_WRITE)
    miss = mapping_miss(fstate, batch, ssd)

    # Die placement. Reads go where the data lives (address-hash channel
    # striping); writes go wherever a free page is open — a page-mapped
    # FTL allocates log-structured, round-robin across dies, so even a
    # Zipf-hot write stream spreads over the array instead of hammering
    # one die. ``prog_seq`` carries the allocation cursor across epochs.
    chip = chip_of(batch.lba, ssd)
    w_rank = jnp.cumsum(is_write.astype(jnp.int32)) - 1
    w_chip = (fstate.prog_seq + jnp.maximum(w_rank, 0)) % k
    chip = jnp.where(is_write, w_chip, chip)
    cost = jnp.where(is_write, jnp.float32(ssd.flash_program_us), 0.0)
    cost = cost + jnp.where(miss, jnp.float32(ssd.flash_read_us), 0.0)
    event = cost > 0.0

    # Queue event rows per die (dispatch order within a die); rows without
    # an event sort into a trailing pseudo-segment and touch nothing.
    key = jnp.where(event, chip, jnp.int32(k))
    if use_pallas_flash:
        from repro.kernels import ops as kops  # lazy: pulls in pallas

        busy, new_cursors = kops.die_contention(
            arrival, cost, jnp.clip(key, 0, k - 1), event,
            fstate.chip_busy,
        )
        chip_busy = new_cursors
    elif use_counting_sort:
        # Counting-sort layout: same stable segment-major permutation as
        # the sort (segops.counting_positions), with the three sorted-
        # side gathers fused into one stacked scatter and the unsort
        # done as a gather by the (inverse) position permutation.
        position, rank_in_key, _, _ = counting_positions(key, k + 1)
        page = jnp.stack(
            [
                arrival,
                cost,
                fstate.chip_busy[jnp.clip(key, 0, k - 1)],
                (rank_in_key == 0).astype(jnp.float32),
            ],
            axis=-1,
        )
        n = key.shape[0]
        s = jnp.zeros((n, 4), jnp.float32).at[position].set(
            page, mode="drop"
        )
        busy_sorted = queueing_scan(
            s[:, 0], s[:, 1], s[:, 3] > 0.0, s[:, 2],
            use_pallas=use_pallas,
        )
        busy = busy_sorted[position]
    else:
        order, heads, _ = sort_by_segment(key)
        safe = jnp.clip(key[order], 0, k - 1)
        busy_sorted = queueing_scan(
            arrival[order], cost[order], heads, fstate.chip_busy[safe],
            use_pallas=use_pallas,
        )
        busy = jnp.zeros_like(busy_sorted).at[order].set(
            busy_sorted, mode="drop"
        )
    if not use_pallas_flash:
        # Kept on the original layout even under compaction: the scan's
        # per-row busy values are not float-guaranteed monotone within a
        # die, so "gather the last sorted row" could pick a different
        # (tied) maximum — segment_max reproduces the reference exactly.
        chip_busy = jnp.maximum(
            fstate.chip_busy,
            jax.ops.segment_max(
                jnp.where(event, busy, NEG),
                jnp.clip(key, 0, k - 1),
                num_segments=k,
            ),
        )

    # Epoch-start view for non-event rows: reads contend with die work
    # scheduled in *previous* epochs but are otherwise already priced.
    epoch_view = jnp.maximum(arrival, fstate.chip_busy[chip])
    flash_done = jnp.where(
        is_write,
        busy,
        jnp.where(miss, busy + (target - arrival), epoch_view),
    )
    flash_done = jnp.where(valid, flash_done, 0.0)

    # --- page-pool accounting + greedy GC (once per epoch) ----------------
    cap = jnp.float32(ssd.num_blocks)
    phys = jnp.float32(ssd.phys_pages)
    n_w = jnp.sum(is_write.astype(jnp.float32))
    # A write consumes one free page; it creates a live page unless it
    # overwrites an already-live logical page (probability valid/cap under
    # uniform addressing), in which case the old copy turns invalid.
    valid_pages = jnp.minimum(
        fstate.valid_pages + n_w * (1.0 - fstate.valid_pages / cap), cap
    )
    free_pages = fstate.free_pages - n_w
    gc_count = fstate.gc_count
    if ssd.gc_watermark > 0.0:
        # Greedy victim selection under uniform invalidation: a victim
        # block's live fraction tracks overall utilization, so each
        # collection migrates live*pages_per_block pages (read + program
        # each), erases the block, and nets (1-live)*pages_per_block
        # fresh pages. Enough collections run back-to-back to restore the
        # watermark; their cost lands on the dies (spread evenly — each
        # die collects its share of victims) starting after this epoch's
        # newest dispatch.
        live = jnp.clip(valid_pages / phys, 0.0, 1.0)
        net = jnp.maximum(ssd.pages_per_block * (1.0 - live), 1.0)
        per_gc_us = (
            ssd.pages_per_block
            * live
            * (ssd.flash_read_us + ssd.flash_program_us)
            + ssd.flash_erase_us
        )
        invalid = jnp.maximum(phys - free_pages - valid_pages, 0.0)
        deficit = jnp.float32(ssd.gc_watermark) * phys - free_pages
        n_gc = jnp.ceil(jnp.maximum(deficit, 0.0) / net)
        n_gc = jnp.clip(n_gc, 0.0, jnp.floor(invalid / net))
        free_pages = free_pages + n_gc * net
        t_now = jnp.max(jnp.where(valid, arrival, 0.0))
        chip_busy = jnp.where(
            n_gc > 0.0,
            jnp.maximum(chip_busy, t_now) + n_gc * per_gc_us / k,
            chip_busy,
        )
        gc_count = gc_count + n_gc

    new_state = FlashState(
        chip_busy=chip_busy,
        free_pages=free_pages,
        valid_pages=valid_pages,
        io_seq=fstate.io_seq + jnp.sum(valid).astype(jnp.int32),
        prog_seq=(fstate.prog_seq + jnp.sum(is_write.astype(jnp.int32))) % k,
        gc_count=gc_count,
    )
    return new_state, flash_done
