"""Frontend: submission-queue rings, doorbells, and request fetching.

The submission half of the queue-pair layer — ``qp.CQRings`` is the
symmetric completion half (SQ q pairs with CQ q). SQ entries live in
contiguous ring buffers (the CQR-bit analogue — paper §IV-B), so a
coalesced fetch of n entries is a single bulk transfer whose
virtual-time cost is ``txn_base + n*sqe_bytes/bw`` instead of n separate
transactions. The *distributed* frontend partitions SQs across service units
and fetches all units' SQs in parallel; the *centralized* baseline models
NVMeVirt's single dispatcher that serializes over every SQ and fetches one
entry per transaction.

Fetching is op-agnostic: each ring entry carries its NVMe ``opcode``
(OP_READ/OP_WRITE) end to end, so the downstream pipeline stages — and in
particular the flash backend, which prices programs and GC — see the
read/write mix exactly as submitted.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    EngineConfig,
    PlatformModel,
    RequestBatch,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SQRings:
    """Struct-of-arrays NVMe submission queues (one ring per SQ)."""

    submit_time: jax.Array  # (Q, D) f32 — virtual time the entry was posted
    opcode: jax.Array       # (Q, D) i32
    lba: jax.Array          # (Q, D) i32
    nblocks: jax.Array      # (Q, D) i32
    buf_id: jax.Array       # (Q, D) i32
    req_id: jax.Array       # (Q, D) i32
    tenant: jax.Array       # (Q, D) i32 — QoS/tenant class of the entry
    head: jax.Array         # (Q,) i32 free-running consumer index
    tail: jax.Array         # (Q,) i32 free-running producer index (doorbell)

    @property
    def num_sqs(self) -> int:
        return self.submit_time.shape[0]

    @property
    def depth(self) -> int:
        return self.submit_time.shape[1]

    @staticmethod
    def empty(num_sqs: int, depth: int) -> "SQRings":
        z = jnp.zeros((num_sqs, depth), jnp.int32)
        return SQRings(
            submit_time=jnp.full((num_sqs, depth), 3e38, jnp.float32),
            opcode=z, lba=z, nblocks=jnp.ones_like(z), buf_id=z, req_id=z,
            tenant=z,
            head=jnp.zeros((num_sqs,), jnp.int32),
            tail=jnp.zeros((num_sqs,), jnp.int32),
        )


def submit(
    rings: SQRings,
    sq_id: jax.Array,       # (M,) i32 target SQ per new entry
    submit_time: jax.Array,  # (M,) f32
    opcode: jax.Array,
    lba: jax.Array,
    nblocks: jax.Array,
    buf_id: jax.Array,
    req_id: jax.Array,
    valid: jax.Array,        # (M,) bool
    tenant: jax.Array | None = None,  # (M,) i32 QoS class (None = 0)
) -> SQRings:
    """Append entries to their SQs (ring the doorbells).

    Entries targeting the same SQ are appended in array order; callers must
    pre-sort per-SQ batches by submit time to model in-order posting.
    """
    # Per-entry offset within its SQ = number of earlier valid entries
    # targeting the same SQ (within-segment rank, O(M log M)).
    from repro.core.segops import segment_rank

    q = rings.num_sqs
    if tenant is None:
        tenant = jnp.zeros_like(sq_id)
    sq_key = jnp.where(valid, sq_id, q)
    offset = segment_rank(sq_key)
    pos = (rings.tail[jnp.clip(sq_key, 0, q - 1)] + offset) % rings.depth
    # Invalid rows scatter out of bounds and are dropped (never collide with
    # valid writes).
    pos = jnp.where(valid, pos, rings.depth)
    row = jnp.clip(sq_key, 0, q - 1)

    def scat(field, val):
        return field.at[row, pos].set(val, mode="drop")

    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), sq_key, num_segments=q + 1
    )[:q]
    return dataclasses.replace(
        rings,
        submit_time=scat(rings.submit_time, submit_time),
        opcode=scat(rings.opcode, opcode),
        lba=scat(rings.lba, lba),
        nblocks=scat(rings.nblocks, nblocks),
        buf_id=scat(rings.buf_id, buf_id),
        req_id=scat(rings.req_id, req_id),
        tenant=scat(rings.tenant, tenant),
        tail=rings.tail + counts,
    )


def submit_grouped(
    rings: SQRings,
    submit_time: jax.Array,  # (Q, F) — row q targets SQ q
    opcode: jax.Array,
    lba: jax.Array,
    nblocks: jax.Array,
    buf_id: jax.Array,
    req_id: jax.Array,
    valid: jax.Array,        # (Q, F) bool
    tenant: jax.Array | None = None,  # (Q, F) i32 QoS class (None = 0)
    fused: bool = False,
) -> SQRings:
    """Fast-path append: row q's valid entries go to SQ q in array order.

    Used by the closed-loop engine where resubmissions are naturally SQ-major.
    Rows must be pre-sorted by submit time.

    ``fused`` collapses the seven per-field ring scatters into one
    stacked (Q, F, 7) pass: the six i32 fields ride as raw bits via
    ``bitcast_convert_type`` (scatters move bits, never arithmetic, so
    the round-trip is exact and the rings land bit-identical).
    """
    q, f = submit_time.shape
    if tenant is None:
        tenant = jnp.zeros_like(opcode)
    offset = jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1
    pos = (rings.tail[:, None] + offset) % rings.depth
    pos = jnp.where(valid, pos, rings.depth)  # drop invalid
    rows = jnp.broadcast_to(jnp.arange(q, dtype=jnp.int32)[:, None], (q, f))
    tail = rings.tail + jnp.sum(valid, axis=1, dtype=jnp.int32)

    if fused:
        bits = jax.lax.bitcast_convert_type

        def f32(x):
            return bits(x, jnp.float32)

        page = jnp.stack(
            [
                submit_time, f32(opcode), f32(lba), f32(nblocks),
                f32(buf_id), f32(req_id), f32(tenant),
            ],
            axis=-1,
        )
        stacked = jnp.stack(
            [
                rings.submit_time, f32(rings.opcode), f32(rings.lba),
                f32(rings.nblocks), f32(rings.buf_id), f32(rings.req_id),
                f32(rings.tenant),
            ],
            axis=-1,
        ).at[rows, pos].set(page, mode="drop")

        def i32(x):
            return bits(x, jnp.int32)

        return dataclasses.replace(
            rings,
            submit_time=stacked[..., 0],
            opcode=i32(stacked[..., 1]),
            lba=i32(stacked[..., 2]),
            nblocks=i32(stacked[..., 3]),
            buf_id=i32(stacked[..., 4]),
            req_id=i32(stacked[..., 5]),
            tenant=i32(stacked[..., 6]),
            tail=tail,
        )

    def scat(field, val):
        return field.at[rows, pos].set(val, mode="drop")

    return dataclasses.replace(
        rings,
        submit_time=scat(rings.submit_time, submit_time),
        opcode=scat(rings.opcode, opcode),
        lba=scat(rings.lba, lba),
        nblocks=scat(rings.nblocks, nblocks),
        buf_id=scat(rings.buf_id, buf_id),
        req_id=scat(rings.req_id, req_id),
        tenant=scat(rings.tenant, tenant),
        tail=tail,
    )


def _gather_entries(
    rings: SQRings, nfetch: jax.Array, fetch_width: int
) -> Tuple[RequestBatch, jax.Array]:
    """Gather up to ``nfetch[q]`` entries from each SQ head (SQ-major order).

    Returns a RequestBatch of capacity Q*fetch_width plus the per-row source
    SQ for cost accounting. Arrival times are filled by the caller (they
    depend on the dispatcher schedule).
    """
    q, d = rings.num_sqs, rings.depth
    j = jnp.arange(fetch_width, dtype=jnp.int32)[None, :]        # (1, F)
    pos = (rings.head[:, None] + j) % d                          # (Q, F)
    valid = j < nfetch[:, None]                                  # (Q, F)
    rows = jnp.arange(q, dtype=jnp.int32)[:, None]

    def take(f):
        return f[rows, pos].reshape(-1)

    batch = RequestBatch(
        arrival=take(rings.submit_time),   # provisional: submit time
        sq_id=jnp.broadcast_to(rows, (q, fetch_width)).reshape(-1),
        slot=pos.reshape(-1),
        opcode=take(rings.opcode),
        lba=take(rings.lba),
        nblocks=take(rings.nblocks),
        buf_id=take(rings.buf_id),
        req_id=take(rings.req_id),
        valid=valid.reshape(-1),
        tenant=take(rings.tenant),
    )
    return batch, valid


def fetch_distributed(
    rings: SQRings,
    clock: jax.Array,            # f32 — entries visible iff submit <= clock
    disp_time: jax.Array,        # (U,) f32 dispatcher busy-until cursors
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[SQRings, jax.Array, RequestBatch, jax.Array]:
    """SwarmIO frontend: all units fetch their SQs in parallel, coalesced.

    Returns (rings', disp_time', batch, fetch_done_per_row). Within a unit,
    SQs are drained round-robin in one pass; the unit's dispatcher cursor
    advances by the summed transaction costs. Fetches are coalesced (one
    transaction per SQ) when cfg.coalesced, else one transaction per entry.
    """
    qs, f = cfg.num_sqs, cfg.fetch_width
    u = cfg.num_units
    per_unit = qs // u

    avail = rings.tail - rings.head
    visible = _visible_count(rings, clock, f)
    nfetch = jnp.minimum(jnp.minimum(avail, visible), f)
    # Self-pacing: a dispatcher still busy with its previous pass skips this
    # round; pending entries accumulate and are coalesced into one larger
    # fetch when it next polls (how the real polling loop batches under
    # load — without this, per-pass setup cost is paid per round and the
    # frontend artificially saturates).
    active_u = disp_time <= clock                                # (U,)
    active = jnp.repeat(active_u, per_unit)                      # (Q,)
    nfetch = jnp.where(active, nfetch, 0)
    cost = fetch_cost(nfetch, cfg, plat)
    cost = jnp.where(active, cost, 0.0)

    # Per-unit sequential pass over its SQs: cumulative cost gives each SQ's
    # fetch-completion time.
    cost_u = cost.reshape(u, per_unit)
    cum = jnp.cumsum(cost_u, axis=1)
    start = jnp.maximum(disp_time, clock)                        # (U,)
    fetch_done_sq = (start[:, None] + cum).reshape(qs)           # (Q,)
    disp_time = start + cum[:, -1]

    batch, valid2d = _gather_entries(rings, nfetch, f)
    fetch_done = jnp.repeat(fetch_done_sq, f)
    rings = dataclasses.replace(rings, head=rings.head + nfetch)
    return rings, disp_time, batch, fetch_done


def fetch_centralized(
    rings: SQRings,
    clock: jax.Array,
    disp_time: jax.Array,        # (1,) f32
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[SQRings, jax.Array, RequestBatch, jax.Array]:
    """NVMeVirt baseline: ONE dispatcher serializes over all SQs, one entry
    per transaction (no coalescing), draining each SQ before the next."""
    qs, f = cfg.num_sqs, cfg.fetch_width

    avail = rings.tail - rings.head
    visible = _visible_count(rings, clock, f)
    nfetch = jnp.minimum(jnp.minimum(avail, visible), f)
    nfetch = jnp.where(disp_time[0] <= clock, nfetch, 0)  # self-pacing

    per_entry = _per_entry_cost(cfg, plat)
    cost = nfetch.astype(jnp.float32) * per_entry + plat.doorbell_poll_us
    cum = jnp.cumsum(cost)
    start = jnp.maximum(disp_time[0], clock)
    sq_base = start + cum - cost                                  # (Q,)
    disp_time = (start + cum[-1])[None]

    batch, _ = _gather_entries(rings, nfetch, f)
    # Entry j of SQ q completes fetching at base_q + (j+1)*per_entry.
    j = jnp.arange(f, dtype=jnp.float32)[None, :]
    done = sq_base[:, None] + (j + 1.0) * per_entry
    fetch_done = done.reshape(-1)
    rings = dataclasses.replace(rings, head=rings.head + nfetch)
    return rings, disp_time, batch, fetch_done


def fetch(
    rings: SQRings,
    clock: jax.Array,
    disp_time: jax.Array,
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[SQRings, jax.Array, RequestBatch, jax.Array]:
    """Dispatch to the configured ring frontend — the single fetch entry
    point shared by ``engine_round`` and ``StorageClient`` (divergence
    here would silently break their bit-exact parity contract)."""
    if cfg.frontend == "distributed":
        return fetch_distributed(rings, clock, disp_time, cfg, plat)
    return fetch_centralized(rings, clock, disp_time, cfg, plat)


def fetch_row_units(cfg: EngineConfig) -> jax.Array:
    """(Q*F,) i32 service-unit id per fetch-batch row (SQ-major layout),
    non-decreasing as the pipeline's datapath stage requires."""
    u = cfg.num_units if cfg.frontend == "distributed" else 1
    rows = cfg.num_sqs * cfg.fetch_width
    return jnp.arange(rows, dtype=jnp.int32) // (rows // u)


def _per_entry_cost(cfg: EngineConfig, plat: PlatformModel):
    """Non-coalesced per-SQE fetch cost by transport/engine."""
    if cfg.transport == "host":
        return jnp.float32(
            plat.host_txn_base_us + plat.sqe_bytes / plat.host_bytes_per_us
        )
    if cfg.dsa_fetch:
        return jnp.float32(plat.dsa_sqe_fetch_us)
    return jnp.float32(plat.cpu_sqe_fetch_us)


def fetch_cost(
    nfetch: jax.Array, cfg: EngineConfig, plat: PlatformModel
) -> jax.Array:
    """Virtual-time cost to fetch ``nfetch[q]`` entries from each SQ.

    Coalescing turns per-SQE transactions into one bulk transfer per SQ
    (enabled by CQR-contiguous rings); DSA fetch replaces uncached CPU p2p
    reads with a bulk engine transfer (paper Fig. 13's A and C knobs).
    """
    nf = nfetch.astype(jnp.float32)
    bytes_per_sq = nf * plat.sqe_bytes
    per_entry = nf * _per_entry_cost(cfg, plat)
    if not cfg.coalesced:
        return per_entry + plat.doorbell_poll_us
    if cfg.transport == "host":
        cost = (
            plat.host_txn_base_us + bytes_per_sq / plat.host_bytes_per_us
        )
    elif cfg.dsa_fetch:
        cost = plat.dsa_coal_base_us + bytes_per_sq / plat.dsa_bytes_per_us
    else:
        cost = plat.cpu_coal_base_us + bytes_per_sq * plat.cpu_coal_byte_us
    # An adaptive dispatcher falls back to per-entry fetches when only a
    # few entries are pending (bulk-txn setup would dominate).
    cost = jnp.minimum(cost, per_entry)
    return jnp.where(nfetch > 0, cost, plat.doorbell_poll_us)


def deal_sqs(n: int, cfg: EngineConfig) -> jax.Array:
    """SQ assignment for a flat application batch: request i's SQ, (N,).

    Requests interleave across service units first and then round-robin
    over each unit's SQs, so a small batch spreads over all dispatchers
    instead of serializing behind one unit's SQ-drain pass. Within each
    SQ, assigned requests keep ascending batch order (in-order rings).
    """
    u = cfg.num_units if cfg.frontend == "distributed" else 1
    per_unit = cfg.num_sqs // u
    i = jnp.arange(n, dtype=jnp.int32)
    return (i % u) * per_unit + (i // u) % per_unit


def direct_fetch_times(
    disp_time: jax.Array,        # (U,) f32 dispatcher busy-until cursors
    t_submit: jax.Array,         # (N,) f32 virtual submission times
    valid: jax.Array,            # (N,) bool
    cfg: EngineConfig,
    plat: PlatformModel,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """TEST-ONLY ring-less frontend for directly submitted batches.

    Production consumers submit through the SQ rings; this shortcut
    backs ``DevicePipeline._fetch_direct`` for stage-2-4 unit tests.

    Applications issue a flat batch with no SQ machinery: requests are dealt
    round-robin to the ``U`` service units in contiguous runs, and each
    unit's dispatcher streams them in — one coalesced transaction per
    ``fetch_width`` entries (CQR-contiguous bulk transfer) or one
    transaction per entry when coalescing is off. Cost parameters are the
    same per-entry/coalesced fetch model as the ring frontends.

    Returns (fetch_done (N,), disp_time' (U,), unit (N,)); ``unit`` is
    non-decreasing, as the datapath stage requires.
    """
    n = t_submit.shape[0]
    u = disp_time.shape[0]
    per_unit = -(-n // u)  # ceil
    idx = jnp.arange(n, dtype=jnp.int32)
    unit = idx // per_unit
    rank = idx % per_unit
    if cfg.transport == "host":
        txn = jnp.float32(plat.host_txn_base_us)
        bw = jnp.float32(plat.host_bytes_per_us)
    else:
        txn = jnp.float32(plat.txn_base_us)
        bw = jnp.float32(plat.link_bytes_per_us)
    start = jnp.maximum(t_submit, disp_time[unit])
    if cfg.coalesced:
        # One transaction per fetch_width entries per unit; entries become
        # visible progressively as the bulk transfer streams.
        n_txn = rank // cfg.fetch_width + 1
        fetch_done = (
            start
            + n_txn.astype(jnp.float32) * txn
            + (rank + 1).astype(jnp.float32) * plat.sqe_bytes / bw
        )
    else:
        fetch_done = (
            start + (rank + 1).astype(jnp.float32) * _per_entry_cost(cfg, plat)
        )
    fetch_done = jnp.where(valid, fetch_done, 0.0)
    disp_time = jnp.maximum(
        jax.ops.segment_max(fetch_done, unit, num_segments=u), disp_time
    )
    return fetch_done, disp_time, unit


def _visible_count(rings: SQRings, clock: jax.Array, f: int) -> jax.Array:
    """How many contiguous head entries of each SQ were posted by ``clock``.

    Entries are posted in ring order; an entry is fetchable only when its
    submit_time <= clock, and fetching stops at the first non-visible entry
    (in-order consumption).
    """
    d = rings.depth
    j = jnp.arange(f, dtype=jnp.int32)[None, :]
    pos = (rings.head[:, None] + j) % d
    rows = jnp.arange(rings.num_sqs, dtype=jnp.int32)[:, None]
    t = rings.submit_time[rows, pos]
    in_ring = j < (rings.tail - rings.head)[:, None]
    vis = (t <= clock) & in_ring
    # Count of leading True per row.
    return jnp.sum(jnp.cumprod(vis.astype(jnp.int32), axis=1), axis=1)
