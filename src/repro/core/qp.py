"""Symmetric queue-pair layer: completion-queue rings (the CQ half).

``SQRings`` (frontend.py) models the submission half of an NVMe queue
pair; this module adds the symmetric completion half. Completions are no
longer read straight out of ``PipelineResult`` — the device *posts* a
completion entry to the CQ paired with the request's SQ, rings a CQ
doorbell, and the GPU consumer *polls* the ring and *reaps* the entry.
Three virtual-time effects the implicit completion path could not
express live here:

  * **completion coalescing** — the device batches ``cq_coalesce_n``
    CQEs per doorbell (with a ``cq_coalesce_us`` timer bound on how long
    the oldest pending entry may wait), trading doorbell rate for
    completion latency (paper Fig. 13's fetch-coalescing knob, mirrored
    onto the completion path — fig21);
  * **doorbell serialization** — each doorbell occupies the CQ's
    completion poster for ``cq_doorbell_us`` (a per-CQ single server),
    so an uncoalesced completion stream can throttle delivered IOPS;
  * **GPU poll cost** — the consumer pays ``cq_poll_us`` per reaped
    doorbell batch plus ``cqe_reap_us`` per entry read from the ring.

All accounting is epoch-batched like the rest of the pipeline: one
``post_and_reap`` call prices a whole completed batch, groups form
within the epoch (the engine's poll quantum acts as an implicit flush
timer), and entries whose completion outruns their group's timer are
posted at their own completion time. With the neutral default config
(``QPConfig().neutral``) the layer stores entries but adds zero virtual
time, so pre-QP completion times reproduce bit-exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    NEG,
    lex_sort_by_segment,
    queueing_scan,
    segment_rank,
    segmented_prefix_max,
    sort_by_segment,
    stable_argsort,
)
from repro.core.types import QPConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CQRings:
    """Struct-of-arrays NVMe completion queues (one ring per CQ).

    Mirrors ``SQRings``: CQ q is paired with SQ q. ``head`` is the
    consumer (GPU reap) index, ``tail`` the producer (device post)
    index; both are free-running. ``bell_time`` is the per-CQ
    completion-poster busy-until cursor (doorbell serialization).
    """

    done_time: jax.Array  # (Q, D) f32 — device-side completion time
    visible_time: jax.Array  # (Q, D) f32 — doorbell-visible time
    req_id: jax.Array  # (Q, D) i32
    head: jax.Array  # (Q,) i32 free-running consumer index
    tail: jax.Array  # (Q,) i32 free-running producer index
    bell_time: jax.Array  # (Q,) f32 doorbell-poster busy-until

    @property
    def num_cqs(self) -> int:
        return self.done_time.shape[0]

    @property
    def depth(self) -> int:
        return self.done_time.shape[1]

    @staticmethod
    def empty(num_cqs: int, depth: int) -> "CQRings":
        return CQRings(
            done_time=jnp.full((num_cqs, depth), 3e38, jnp.float32),
            visible_time=jnp.full((num_cqs, depth), 3e38, jnp.float32),
            req_id=jnp.zeros((num_cqs, depth), jnp.int32),
            head=jnp.zeros((num_cqs,), jnp.int32),
            tail=jnp.zeros((num_cqs,), jnp.int32),
            bell_time=jnp.zeros((num_cqs,), jnp.float32),
        )


def _scatter_entries(
    cq: CQRings,
    key: jax.Array,  # (N,) i32 CQ per row, num_cqs for invalid rows
    rank: jax.Array,  # (N,) i32 posting order within the row's CQ
    done: jax.Array,
    visible: jax.Array,
    req_id: jax.Array,
    valid: jax.Array,
    counts: jax.Array | None = None,  # (Q,) i32 valid entries per CQ
    fused: bool = False,
) -> CQRings:
    """Write posted entries into the rings and advance the tails.

    ``counts`` lets the caller hand in per-CQ valid counts it already
    knows (the compacted epoch's block counts) instead of paying a
    segment_sum. ``fused`` replaces the three ring scatters with one
    stacked (N, 3) scatter — the i32 ``req_id`` channel rides as raw
    bits via ``bitcast_convert_type`` (scatters move bits, never
    arithmetic, so the round-trip is exact).
    """
    q, d = cq.num_cqs, cq.depth
    row = jnp.clip(key, 0, q - 1)
    pos = (cq.tail[row] + rank) % d
    pos = jnp.where(valid, pos, d)  # invalid rows drop out of bounds
    if counts is None:
        counts = jax.ops.segment_sum(
            valid.astype(jnp.int32), key, num_segments=q + 1
        )[:q]
    # The consumer polls continuously: every entry posted this epoch
    # is reaped within it, so the head tracks the tail.
    if fused:
        bits = jax.lax.bitcast_convert_type
        page = jnp.stack(
            [done, visible, bits(req_id, jnp.float32)], axis=-1
        )
        rings = jnp.stack(
            [cq.done_time, cq.visible_time, bits(cq.req_id, jnp.float32)],
            axis=-1,
        ).at[row, pos].set(page, mode="drop")
        return dataclasses.replace(
            cq,
            done_time=rings[..., 0],
            visible_time=rings[..., 1],
            req_id=bits(rings[..., 2], jnp.int32),
            tail=cq.tail + counts,
            head=cq.head + counts,
        )
    return dataclasses.replace(
        cq,
        done_time=cq.done_time.at[row, pos].set(done, mode="drop"),
        visible_time=cq.visible_time.at[row, pos].set(visible, mode="drop"),
        req_id=cq.req_id.at[row, pos].set(req_id, mode="drop"),
        tail=cq.tail + counts,
        head=cq.head + counts,
    )


def post_and_reap(
    cq: CQRings,
    cq_id: jax.Array,  # (N,) i32 target CQ (= source SQ) per completion
    done: jax.Array,  # (N,) f32 device-side completion times
    req_id: jax.Array,  # (N,) i32
    valid: jax.Array,  # (N,) bool
    qp: QPConfig,
    posted_rank: jax.Array | None = None,  # (N,) epoch-plan CQ ranks
    fused_sort: bool = False,
    use_pallas: bool = False,
    posted_counts: jax.Array | None = None,  # (Q,) per-CQ valid counts
    fused_scatter: bool = False,
    use_pallas_reap: bool = False,
) -> Tuple[CQRings, jax.Array]:
    """Post one epoch's completions and reap them. Returns (cq', reaped).

    ``reaped[i]`` is when the GPU consumer observes request i's
    completion: device completion -> coalescing group doorbell ->
    doorbell service on the per-CQ poster -> consumer poll + CQE read.
    Invalid rows return 0 and touch nothing.

    ``posted_rank`` lets ``DevicePipeline.process`` hand in the neutral
    path's per-CQ ranks from its epoch sort plan (fetched batches are
    SQ-major, so the ranks come sort-free); ``fused_sort`` replaces the
    non-neutral path's two-sort layout with the fused lexicographic
    sort; ``posted_counts``/``fused_scatter`` (PR 8) skip the per-CQ
    segment_sum and collapse the three ring scatters into one stacked
    pass. All are bit-exact layout changes, not model changes.
    ``use_pallas_reap`` routes the whole neutral posting path (rank +
    ring scatter + counts) through the ``kernels/ops`` fused one-pass
    kernel — pure integer bookkeeping and data movement, exact for any
    inputs (parity-tested in tests/test_segops.py).
    """
    q = cq.num_cqs
    key = jnp.where(valid, cq_id, q)

    if qp.neutral:
        # Transparent completion path: entries are recorded for ring
        # observability, but nothing is ever delayed (bit-exact parity
        # with the pre-QP pipeline by construction).
        if use_pallas_reap:
            from repro.kernels import ops as kops  # lazy: pulls in pallas

            dt, vt, rid, counts = kops.fused_reap(
                cq.done_time, cq.visible_time, cq.req_id, cq.tail,
                key, done, req_id, valid,
            )
            cq = dataclasses.replace(
                cq, done_time=dt, visible_time=vt, req_id=rid,
                tail=cq.tail + counts, head=cq.head + counts,
            )
            return cq, jnp.where(valid, done, 0.0)
        rank = posted_rank if posted_rank is not None else segment_rank(key)
        cq = _scatter_entries(
            cq, key, rank, done, done, req_id, valid,
            counts=posted_counts, fused=fused_scatter,
        )
        return cq, jnp.where(valid, done, 0.0)

    n_coal = qp.cq_coalesce_n

    # CQEs post in completion-time order within each CQ: sort rows by
    # done time, then stable segment sort by CQ (composition keeps the
    # time order inside each segment).
    if fused_sort:
        order, heads, rank = lex_sort_by_segment(key, done)
    else:
        ord1 = stable_argsort(done)
        ord2, heads, rank = sort_by_segment(key[ord1])
        order = ord1[ord2]
    s_done = done[order]
    s_valid = valid[order]
    s_key = key[order]
    safe = jnp.clip(s_key, 0, q - 1)

    # Coalescing groups: contiguous runs of n_coal entries per CQ.
    gheads = heads | (rank % n_coal == 0)
    n = done.shape[0]
    tails = jnp.concatenate([gheads[1:], jnp.ones((1,), bool)])

    # Doorbell fires when the group fills (time of its last member) or
    # its timer expires (first member + cq_coalesce_us), whichever is
    # earlier; an entry completing after that flush posts at its own
    # completion time (it would have been in the next group).
    first = segmented_prefix_max(jnp.where(gheads, s_done, NEG), gheads)
    rev = slice(None, None, -1)
    full = segmented_prefix_max(
        jnp.where(tails, s_done, NEG)[rev], tails[rev]
    )[rev]
    bell_raw = jnp.minimum(full, first + jnp.float32(qp.cq_coalesce_us))
    ready = jnp.maximum(s_done, bell_raw)

    # Doorbell serialization: one cq_doorbell_us of poster time per
    # group, charged at the group head, serialized per CQ.
    cost = jnp.where(gheads & s_valid, jnp.float32(qp.cq_doorbell_us), 0.0)
    posted = queueing_scan(
        ready, cost, heads, cq.bell_time[safe], use_pallas=use_pallas
    )
    bell_time = jnp.maximum(
        cq.bell_time,
        jax.ops.segment_max(
            jnp.where(s_valid, posted, NEG), safe, num_segments=q
        ),
    )

    # Consumer reap: one poll pass per doorbell batch plus a per-CQE
    # ring read, in posting order within the batch.
    reap_rank = (rank % n_coal).astype(jnp.float32)
    reaped_s = (
        posted
        + jnp.float32(qp.cq_poll_us)
        + (reap_rank + 1.0) * jnp.float32(qp.cqe_reap_us)
    )

    cq = dataclasses.replace(
        _scatter_entries(
            cq, s_key, rank, s_done, posted, req_id[order], s_valid,
            # Per-CQ valid counts are layout-independent, so the
            # dispatch-order epoch counts apply to the sorted layout too.
            counts=posted_counts, fused=fused_scatter,
        ),
        bell_time=bell_time,
    )
    reaped = jnp.zeros_like(done).at[order].set(reaped_s, mode="drop")
    return cq, jnp.where(valid, reaped, 0.0)
