"""Segmented scan primitives shared by the timing model and the frontend.

These are the vectorized building blocks that make "aggregated" processing
exact: a segmented inclusive prefix-max (associative, runs in O(log N) depth
via ``lax.associative_scan``) and within-segment rank computation via a
stable sort.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -3e38  # python float: jnp module constants leak into jaxprs


def hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-style integer hash (deterministic per-request randomness).

    Shared by the workload generators and the flash backend's CMT-miss
    model: counter-based hashing needs no PRNG state threaded through the
    engine loop and vmaps cleanly across emulated devices.
    """
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def uniform01(h: jax.Array) -> jax.Array:
    """Map a u32 hash to (0, 1) — open at both ends (safe for log)."""
    return (h.astype(jnp.float32) + 0.5) / 4294967296.0


def segmented_prefix_max(values: jax.Array, heads: jax.Array) -> jax.Array:
    """Inclusive prefix max restarting at each ``heads[i]==True``."""

    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, jnp.maximum(va, vb))

    _, out = jax.lax.associative_scan(combine, (heads, values))
    return out


def sort_by_segment(
    key: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable sort by integer segment key.

    Returns (order, heads, rank): ``order`` permutes inputs to segment-major
    layout preserving original order within segments; ``heads`` flags segment
    starts in sorted layout; ``rank`` is the within-segment position.
    """
    n = key.shape[0]
    order = jnp.argsort(key, stable=True)
    s_key = key[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.ones((1,), bool), s_key[1:] != s_key[:-1]])
    seg_start = segmented_prefix_max(
        jnp.where(heads, idx, 0).astype(jnp.float32), heads
    ).astype(jnp.int32)
    rank = idx - seg_start
    return order, heads, rank


def segment_rank(key: jax.Array) -> jax.Array:
    """Within-segment rank in original order (count of earlier equal keys)."""
    n = key.shape[0]
    order, _, rank = sort_by_segment(key)
    out = jnp.zeros((n,), jnp.int32).at[order].set(rank)
    return out


def queueing_scan(
    ready: jax.Array,
    cost: jax.Array,
    heads: jax.Array,
    seed: jax.Array,
) -> jax.Array:
    """Exact single-server queueing recurrence, vectorized per segment.

    Solves ``busy_j = max(ready_j, busy_{j-1}) + cost_j`` (with
    ``busy_{-1} = seed`` at each segment head) via function composition in the
    (max,+) semiring: each element is the map ``x -> max(a_j, x + c_j)`` with
    ``a_j = ready_j + cost_j``; composition
    ``(a2,c2) ∘ (a1,c1) = (max(a2, a1 + c2), c1 + c2)`` is associative, so an
    ``associative_scan`` yields every ``busy_j`` in O(log N) depth. This is
    the aggregated-update closed form generalized to heterogeneous service
    costs (used by the worker/DSA backend model); the timing model is the
    constant-cost special case.

    ``seed`` must be broadcastable to per-element values (pass e.g.
    ``seed_per_element`` gathered for each row's segment).
    """
    a = ready + cost
    a = jnp.where(heads, jnp.maximum(a, seed + cost), a)

    def combine(l, r):
        fl, al, cl = l
        fr, ar, cr = r
        a_ = jnp.where(fr, ar, jnp.maximum(ar, al + cr))
        c_ = jnp.where(fr, cr, cl + cr)
        return fl | fr, a_, c_

    _, busy, _ = jax.lax.associative_scan(combine, (heads, a, cost))
    return busy
