"""Segmented scan primitives shared by the timing model and the frontend.

These are the vectorized building blocks that make "aggregated" processing
exact: a segmented inclusive prefix-max (associative, runs in O(log N) depth
via ``lax.associative_scan``) and within-segment rank computation via a
stable sort.

Wall-clock hot-path helpers live here too:

  * ``SortPlan`` — a reusable (order, heads, rank) triple so stages that
    segment the same epoch batch on the same key sort once and share the
    layout (``presorted_plan`` skips the sort entirely for keys the
    caller knows are already non-decreasing, e.g. the SQ-major service
    unit ids of a fetched batch);
  * ``lex_sort_by_segment`` — the fused one-pass replacement for the
    "stable sort by time, then stable segment sort by key" two-sort
    idiom (qp.py's CQ layout, fabric.py's frame layout): a single
    lexicographic ``lax.sort`` producing the bit-identical permutation;
  * ``queueing_scan(..., use_pallas=True)`` — routes the (max,+) scan
    core through the ``kernels/seg_scan`` Pallas kernel via the exact
    prefix-max reduction ``busy = S + segmax(a - S)`` with
    ``S = cumsum(cost)``;
  * ``CompactPlan`` / ``compact_epoch`` — the PR-8 epoch-compaction
    layout: valid rows gathered to a dense prefix (invalid rows packed
    after, in original order) so downstream stages operate on a dense
    valid block instead of a full-width masked epoch;
  * ``counting_sort_plan`` — a sort-free ``make_sort_plan`` for small
    integer key alphabets (S segments): one (S, N) one-hot cumsum
    replaces the O(N log N) stable sort, producing the bit-identical
    permutation (stable counting sort IS the stable sort);
  * ``block_masked_rank`` / ``block_counts`` — ``masked_presorted_rank``
    and per-segment valid counts specialized to fixed-width segment
    blocks (the ring-major epoch layout, N = Q * F): a row-contiguous
    (Q, F) cumsum replaces the segmented scans.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -3e38  # python float: jnp module constants leak into jaxprs


def hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-style integer hash (deterministic per-request randomness).

    Shared by the workload generators and the flash backend's CMT-miss
    model: counter-based hashing needs no PRNG state threaded through the
    engine loop and vmaps cleanly across emulated devices.
    """
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def uniform01(h: jax.Array) -> jax.Array:
    """Map a u32 hash to (0, 1) — open at both ends (safe for log)."""
    return (h.astype(jnp.float32) + 0.5) / 4294967296.0


def stable_argsort(x: jax.Array, axis: int = -1) -> jax.Array:
    """THE repo-wide argsort: always stable, always through this module.

    Every permutation the emulator prices virtual time through must be
    deterministic and tie-stable (program order on equal keys) — an
    unstable sort would reorder equal-key requests between backends and
    silently break the bit-exactness contract. repro-lint rule RL003
    bans raw ``jnp.argsort``/``jnp.sort``/``lax.sort`` outside this
    module so the discipline is machine-enforced; call sites that just
    need a permutation use this wrapper, and sites that reuse one layout
    across stages build a ``SortPlan``. ``stable=True`` is jnp's default
    (bit-identical), stated explicitly here so the contract survives
    upstream default changes.
    """
    return jnp.argsort(x, axis=axis, stable=True)


def segmented_prefix_max(values: jax.Array, heads: jax.Array) -> jax.Array:
    """Inclusive prefix max restarting at each ``heads[i]==True``."""

    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, jnp.maximum(va, vb))

    _, out = jax.lax.associative_scan(combine, (heads, values))
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SortPlan:
    """Reusable segment-major layout of one epoch batch for one sort key.

    ``order`` permutes inputs to segment-major layout preserving original
    order within segments; ``heads`` flags segment starts in the sorted
    layout; ``rank`` is the within-segment position there. Stages that
    segment the same batch on the same key build the plan once (in
    ``DevicePipeline.process``) and share it instead of re-sorting.
    """

    order: jax.Array  # (N,) i32 permutation into segment-major layout
    heads: jax.Array  # (N,) bool segment starts in sorted layout
    rank: jax.Array   # (N,) i32 within-segment position in sorted layout


def _heads_rank(s_key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(heads, rank) of an already segment-major key array."""
    n = s_key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.ones((1,), bool), s_key[1:] != s_key[:-1]])
    seg_start = segmented_prefix_max(
        jnp.where(heads, idx, 0).astype(jnp.float32), heads
    ).astype(jnp.int32)
    return heads, idx - seg_start


def make_sort_plan(key: jax.Array) -> SortPlan:
    """Stable sort by integer segment key, packaged as a reusable plan."""
    order = jnp.argsort(key, stable=True)
    heads, rank = _heads_rank(key[order])
    return SortPlan(order=order, heads=heads, rank=rank)


def presorted_plan(key: jax.Array) -> SortPlan:
    """SortPlan for a key the caller knows is already non-decreasing.

    Skips the O(N log N) sort entirely — ``order`` is the identity — and
    derives heads/rank with one O(log N)-depth scan. Bit-identical to
    ``make_sort_plan`` whenever the precondition holds (the stable sort
    of a sorted key is the identity permutation).
    """
    n = key.shape[0]
    heads, rank = _heads_rank(key)
    return SortPlan(
        order=jnp.arange(n, dtype=jnp.int32), heads=heads, rank=rank
    )


def sort_by_segment(
    key: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable sort by integer segment key.

    Returns (order, heads, rank): ``order`` permutes inputs to segment-major
    layout preserving original order within segments; ``heads`` flags segment
    starts in sorted layout; ``rank`` is the within-segment position.
    """
    plan = make_sort_plan(key)
    return plan.order, plan.heads, plan.rank


def lex_sort_by_segment(
    key: jax.Array,
    t: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (key, t)-lexicographic segment sort — one ``lax.sort`` pass.

    Bit-identical to the two-sort composition

        ord1 = argsort(t, stable=True)
        ord2, heads, rank = sort_by_segment(key[ord1])
        order = ord1[ord2]

    used by the CQ and fabric frame layouts: a stable sort by time
    followed by a stable segment sort by key IS the stable lexicographic
    sort by (key, t). Fusing halves the sort work and drops the two
    intermediate gathers per hop.
    """
    n = key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    s_key, _, order = jax.lax.sort(
        (key, t, idx), num_keys=2, is_stable=True
    )
    heads, rank = _heads_rank(s_key)
    return order, heads, rank


def segment_rank(key: jax.Array) -> jax.Array:
    """Within-segment rank in original order (count of earlier equal keys)."""
    n = key.shape[0]
    order, _, rank = sort_by_segment(key)
    out = jnp.zeros((n,), jnp.int32).at[order].set(rank, mode="drop")
    return out


def masked_presorted_rank(
    group: jax.Array,   # (N,) i32 non-decreasing group ids
    valid: jax.Array,   # (N,) bool
) -> jax.Array:
    """``segment_rank(where(valid, group, G))`` for valid rows, sort-free.

    The queue-pair completion path ranks each epoch's valid completions
    within their (already SQ-major, hence non-decreasing) CQ groups;
    ``segment_rank`` pays a full stable sort for it. Because ``group``
    is non-decreasing, the rank of a valid row is just the count of
    earlier valid rows in its group — one cumulative sum plus one
    segmented scan. Invalid rows return 0 (callers drop them before the
    rank is ever used; ``segment_rank`` would place them in a trailing
    pseudo-segment instead).
    """
    exc = jnp.cumsum(valid.astype(jnp.int32)) - valid.astype(jnp.int32)
    heads, _ = _heads_rank(group)
    base = segmented_prefix_max(
        jnp.where(heads, exc, 0).astype(jnp.float32), heads
    ).astype(jnp.int32)
    return jnp.where(valid, exc - base, 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompactPlan:
    """Dense-prefix layout of one epoch's valid rows.

    ``pos[i]`` is row i's slot in the compacted layout: valid rows land
    at ``0 .. n_valid-1`` in original order, invalid rows pack after in
    original order (``pos`` is a true permutation, so dense-side
    scatters by ``pos`` and gathers ``dense[pos]`` are exact inverses).
    Built once per epoch (``compact_epoch``) and threaded through the
    stages that only do work proportional to the valid rows.
    """

    pos: jax.Array      # (N,) i32 permutation into the dense layout
    n_valid: jax.Array  # () i32 number of valid rows


def compact_epoch(valid: jax.Array) -> CompactPlan:
    """Build the dense-prefix compaction plan for one epoch's validity."""
    vi = valid.astype(jnp.int32)
    cs = jnp.cumsum(vi)
    n_valid = cs[-1]
    idx = jnp.arange(valid.shape[0], dtype=jnp.int32)
    pos = jnp.where(valid, cs - 1, n_valid + (idx - cs))
    return CompactPlan(pos=pos, n_valid=n_valid)


def counting_positions(
    key: jax.Array, num_keys: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Stable counting-sort positions for a small integer key alphabet.

    Returns ``(position, rank_in_key, counts, offsets)``: row i of the
    input lands at ``position[i]`` in the segment-major layout (segments
    ordered by key value, original order preserved within a segment —
    exactly the stable-sort permutation), ``rank_in_key[i]`` is its
    within-segment rank there, ``counts[k]``/``offsets[k]`` are segment
    sizes and segment start offsets. One (num_keys, N) one-hot cumsum
    along the contiguous axis replaces the stable sort; cost is
    O(num_keys * N) flops at O(1) sort depth, a win whenever the
    alphabet is small (service units, flash chips, CQ ids).
    """
    n = key.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    oh = key[None, :] == jnp.arange(num_keys, dtype=key.dtype)[:, None]
    csum = jnp.cumsum(oh.astype(jnp.int32), axis=1)  # (S, N) contiguous
    counts = csum[:, -1]
    offsets = jnp.cumsum(counts) - counts
    rank_in_key = csum[key, idx] - 1
    return offsets[key] + rank_in_key, rank_in_key, counts, offsets


def counting_sort_plan(key: jax.Array, num_keys: int) -> SortPlan:
    """``make_sort_plan`` via counting sort — bit-identical for
    ``0 <= key < num_keys`` (stable counting sort IS the stable sort)."""
    n = key.shape[0]
    position, rank_in_key, _, _ = counting_positions(key, num_keys)
    idx = jnp.arange(n, dtype=jnp.int32)
    page = jnp.stack(
        [idx, rank_in_key, (rank_in_key == 0).astype(jnp.int32)], axis=-1
    )
    s = jnp.zeros((n, 3), jnp.int32).at[position].set(page, mode="drop")
    return SortPlan(order=s[:, 0], rank=s[:, 1], heads=s[:, 2].astype(bool))


def block_masked_rank(valid: jax.Array, block: int) -> jax.Array:
    """``masked_presorted_rank`` for fixed-width segment blocks.

    When the (non-decreasing) group key is ``arange(N) // block`` — the
    ring-major epoch layout, where segment f of width ``block`` occupies
    rows ``f*block .. (f+1)*block - 1`` — the masked rank is a plain
    row-wise exclusive cumsum of the validity reshaped to (N//block,
    block). Bit-identical to ``masked_presorted_rank`` there (integer
    counting; invalid rows return 0).
    """
    v = valid.reshape(-1, block).astype(jnp.int32)
    rank = (jnp.cumsum(v, axis=1) - v).reshape(-1)
    return jnp.where(valid, rank, 0)


def block_counts(valid: jax.Array, block: int) -> jax.Array:
    """Per-segment valid counts for fixed-width segment blocks.

    ``segment_sum(valid, arange(N) // block, N // block)`` as one
    row-wise reduction — exact (integer sums associate freely).
    """
    return jnp.sum(valid.reshape(-1, block).astype(jnp.int32), axis=1)


def queueing_scan_via_segmax(
    ready: jax.Array,
    cost: jax.Array,
    heads: jax.Array,
    seed: jax.Array,
    segmax_fn=segmented_prefix_max,
) -> jax.Array:
    """``queueing_scan`` reduced to one segmented prefix max.

    With ``S_j = cumsum(cost)_j`` (a plain, unsegmented inclusive sum)
    the (max,+) recurrence has the closed form

        busy_j = S_j + max_{i <= j, same segment} (a_i - S_i)

    because ``a_i + (c_{i+1} + ... + c_j) = a_i - S_i + S_j``. The max
    is segmented, so cross-segment terms never mix and the global cumsum
    is safe. This is the form the Pallas kernel accelerates: max is
    exactly associative in floats, so ``kernels/seg_scan.seg_scan`` is
    bit-identical to ``segmented_prefix_max`` here for *any* inputs —
    the only float divergence vs the ``lax.associative_scan`` reference
    path is the re-association of the cost sums.
    """
    a = ready + cost
    a = jnp.where(heads, jnp.maximum(a, seed + cost), a)
    s = jnp.cumsum(cost.astype(jnp.float32))
    return s + segmax_fn(a - s, heads)


def _pallas_segmax(values: jax.Array, heads: jax.Array) -> jax.Array:
    from repro.kernels import ops as kops  # lazy: pulls in pallas

    return kops.seg_scan(values.astype(jnp.float32), heads)


def queueing_scan(
    ready: jax.Array,
    cost: jax.Array,
    heads: jax.Array,
    seed: jax.Array,
    use_pallas: bool = False,
) -> jax.Array:
    """Exact single-server queueing recurrence, vectorized per segment.

    Solves ``busy_j = max(ready_j, busy_{j-1}) + cost_j`` (with
    ``busy_{-1} = seed`` at each segment head) via function composition in the
    (max,+) semiring: each element is the map ``x -> max(a_j, x + c_j)`` with
    ``a_j = ready_j + cost_j``; composition
    ``(a2,c2) ∘ (a1,c1) = (max(a2, a1 + c2), c1 + c2)`` is associative, so an
    ``associative_scan`` yields every ``busy_j`` in O(log N) depth. This is
    the aggregated-update closed form generalized to heterogeneous service
    costs (used by the worker/DSA backend model); the timing model is the
    constant-cost special case.

    ``seed`` must be broadcastable to per-element values (pass e.g.
    ``seed_per_element`` gathered for each row's segment).

    ``use_pallas=True`` (EngineConfig.use_pallas_segscan) routes the
    scan core through the ``kernels/seg_scan`` Pallas kernel via the
    segmented-prefix-max reduction (``queueing_scan_via_segmax``); the
    ``lax.associative_scan`` path below is the reference fallback.
    """
    if use_pallas:
        return queueing_scan_via_segmax(
            ready, cost, heads, seed, segmax_fn=_pallas_segmax
        )
    a = ready + cost
    a = jnp.where(heads, jnp.maximum(a, seed + cost), a)

    def combine(l, r):
        fl, al, cl = l
        fr, ar, cr = r
        a_ = jnp.where(fr, ar, jnp.maximum(ar, al + cr))
        c_ = jnp.where(fr, cr, cl + cr)
        return fl | fr, a_, c_

    _, busy, _ = jax.lax.associative_scan(combine, (heads, a, cost))
    return busy
