"""NVMeVirt simple timing model + SwarmIO aggregated batch updates.

Semantics (paper Fig. 2b), for request i in dispatch order on instance k:

    start_i      = max(arrival_i, busy[k])
    busy[k]      = start_i + Sched
    completion_i = max(start_i + Sched, arrival_i + L_min)

Instance assignment follows the paper's §IV-D wording — "requests are
assigned to scheduling instances in the order in which they appear in the
SQ" — i.e. a round-robin cursor over the K instances (``routing=
"round_robin"``). An ``lba_hash`` policy (channel striping by address) is
kept for sensitivity studies; it exposes hash-imbalance idle time.

``per_request_update`` executes the recurrence literally with a sequential
``lax.scan`` (the NVMeVirt baseline). ``aggregated_update`` computes the
*identical* result for a whole fetched batch with one segmented (max,+)
prefix scan and a single scatter into the shared state — the paper's "enter
the critical section once per set of requests", made exact by the closed
form

    b_j = max(arrival_j - j*Sched, b_{j-1}),  b_{-1} = busy[k]
    start_j = b_j + j*Sched,   busy'[k] = b_last + m_k*Sched

where j is the within-instance rank inside the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import (
    NEG,
    compact_epoch,
    segmented_prefix_max,
    sort_by_segment,
)
from repro.core.types import RequestBatch, SSDConfig, TimingState


def lba_hash_instance(lba: jax.Array, n_instances: int) -> jax.Array:
    """Map a request to an instance by address (channel striping)."""
    h = (lba.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(n_instances)).astype(jnp.int32)


def assign_rr(
    rr: jax.Array, valid: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Round-robin instance assignment in dispatch order.

    Invalid rows receive an arbitrary instance (masked downstream) and do
    not advance the cursor. Returns (inst, rr')."""
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    inst = (rr + jnp.maximum(pos, 0)) % k
    n_valid = jnp.sum(valid.astype(jnp.int32))
    return inst.astype(jnp.int32), (rr + n_valid) % k


def assign_instances(
    state: TimingState, batch: RequestBatch, ssd: SSDConfig
) -> Tuple[jax.Array, jax.Array]:
    """Instance per request (dispatch order) + advanced round-robin cursor."""
    k = ssd.n_instances
    if ssd.routing == "lba_hash":
        return lba_hash_instance(batch.lba, k), state.rr
    return assign_rr(state.rr, batch.valid, k)


# ---------------------------------------------------------------------------
# Baseline: per-request sequential updates (NVMeVirt).
# ---------------------------------------------------------------------------

def per_request_update(
    state: TimingState, batch: RequestBatch, ssd: SSDConfig
) -> Tuple[TimingState, jax.Array]:
    """Sequential per-request timing updates. Returns (state', completion)."""
    sched = jnp.float32(ssd.sched_us)
    lmin = jnp.float32(ssd.l_min_us)
    inst, rr = assign_instances(state, batch, ssd)

    def step(busy, x):
        arrival, k, valid = x
        start = jnp.maximum(arrival, busy[k])
        new_b = jnp.where(valid, start + sched, busy[k])
        busy = busy.at[k].set(new_b, mode="drop")
        comp = jnp.maximum(start + sched, arrival + lmin)
        return busy, jnp.where(valid, comp, jnp.float32(0))

    busy, completion = jax.lax.scan(
        step, state.busy_until, (batch.arrival, inst, batch.valid)
    )
    return TimingState(busy, rr), completion


# ---------------------------------------------------------------------------
# SwarmIO: aggregated batch updates via segmented (max,+) scan.
# ---------------------------------------------------------------------------

def _sorted_batch_core(
    busy_init: jax.Array,  # (K,) f32
    s_arr: jax.Array,      # (N,) f32 arrivals in instance-major layout
    s_inst: jax.Array,     # (N,) i32 instance key, K for invalid rows
    s_valid: jax.Array,    # (N,) bool
    head: jax.Array,       # (N,) bool segment starts
    rank: jax.Array,       # (N,) i32 within-segment rank
    order: jax.Array,      # (N,) i32 sorted index -> dispatch index
    ssd: SSDConfig,
) -> Tuple[jax.Array, jax.Array]:
    """The (max,+) closed form on an instance-major layout.

    Shared verbatim by the stable-sort reference and the sort-free
    compacted path: the float expression tree must be *identical* in
    both (same ops, shapes, dtypes), because backend instruction
    selection (e.g. folding ``b + rank*sched`` into an FMA) rounds
    differently per pattern — two algebraically equal formulations can
    drift one ULP apart and cascade through the closed loop.
    """
    # repro-lint: pinned-expr sorted-batch-core
    k = ssd.n_instances
    sched = jnp.float32(ssd.sched_us)
    lmin = jnp.float32(ssd.l_min_us)

    # Seed each segment with its instance's current busy time: emulate the
    # b_{-1} = busy[k] seed by max-ing the head element against busy[k].
    safe_inst = jnp.clip(s_inst, 0, k - 1)
    seed = busy_init[safe_inst]
    a = s_arr - rank.astype(jnp.float32) * sched
    a = jnp.where(head, jnp.maximum(a, seed), a)
    a = jnp.where(s_valid, a, NEG)
    # Invalid rows were sorted to a trailing pseudo-segment (key == K), so
    # they cannot poison real segments; they contribute NEG regardless.
    b = segmented_prefix_max(a, head)

    start = b + rank.astype(jnp.float32) * sched
    comp_sorted = jnp.maximum(start + sched, s_arr + lmin)
    comp_sorted = jnp.where(s_valid, comp_sorted, jnp.float32(0))

    # New busy state: last valid element of each real segment.
    # busy'[k] = b_last + m_k * sched, where m_k = count of valid in segment.
    seg_counts = jax.ops.segment_sum(
        s_valid.astype(jnp.float32), safe_inst, num_segments=k
    )
    last_b = jax.ops.segment_max(
        jnp.where(s_valid, b, NEG), safe_inst, num_segments=k
    )
    new_busy = jnp.where(
        seg_counts > 0, last_b + seg_counts * sched, busy_init
    )
    # repro-lint: end-pinned-expr

    # Unsort completions back to dispatch order.
    completion = jnp.zeros_like(comp_sorted).at[order].set(
        comp_sorted, mode="drop"
    )
    return completion, new_busy


def aggregated_batch_times(
    busy_init: jax.Array,
    arrival: jax.Array,
    inst: jax.Array,
    valid: jax.Array,
    ssd: SSDConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Vectorized exact batch timing. Returns (completion, new_busy).

    ``busy_init`` is the (K,) shared busy-until state; requests are taken in
    array order (the dispatch order). Invalid rows do not affect anything.
    """
    k = ssd.n_instances
    # Sort by (instance, original order) — stable sort of instance suffices.
    inst_sorted_key = jnp.where(valid, inst, jnp.int32(k))  # invalid last
    order, head, rank = sort_by_segment(inst_sorted_key)
    return _sorted_batch_core(
        busy_init, arrival[order], inst_sorted_key[order], valid[order],
        head, rank, order, ssd,
    )


def compact_rr_batch_times(
    busy_init: jax.Array,  # (K,) f32 shared busy-until state
    arrival: jax.Array,    # (N,) f32 dispatch-order arrivals
    rr: jax.Array,         # ()  i32 round-robin cursor
    valid: jax.Array,      # (N,) bool
    ssd: SSDConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-free aggregated timing on the compacted epoch (PR 8).

    Round-robin routing assigns the p-th *valid* request (dispatch
    order) to instance ``(rr + p) % K``, so the instance-major stable
    sort ``aggregated_batch_times`` pays an argsort for has a closed
    form: instance c's requests are the valid ranks ``p = (c-rr)%K,
    (c-rr)%K + K, ...`` in dispatch order, and a request's sorted slot
    is ``offset[c] + p // K``. One ``compact_epoch`` cumsum plus a
    stacked scatter builds the whole (order, key, rank) layout; the
    float arithmetic then runs through the *same* ``_sorted_batch_core``
    as the reference — deliberately, so both paths present the backend
    with the identical expression tree (see the core's docstring: an
    algebraically equal reformulation compiled with different FMA
    contraction one ULP apart). Bit-identical to
    ``aggregated_batch_times`` with round-robin assignment, pinned by
    tests/test_segops.py. Returns ``(completion, new_busy, rr')``.
    """
    k = ssd.n_instances
    n = arrival.shape[0]
    plan = compact_epoch(valid)
    pos, n_valid = plan.pos, plan.n_valid
    idx = jnp.arange(n, dtype=jnp.int32)

    # Per-instance valid counts and exclusive offsets: instance c's
    # column of the dense round-robin deal is q = (c - rr) % K, holding
    # ceil((n_valid - q) / K) requests.
    q_of_c = (jnp.arange(k, dtype=jnp.int32) - rr) % k
    m_c = jnp.maximum(-(-(n_valid - q_of_c) // k), 0)
    offsets = jnp.cumsum(m_c) - m_c

    # Each dispatch row's slot in the instance-major layout: valid rows
    # by (instance offset + within-instance rank), invalid rows keep
    # their compacted position (they pack after n_valid in dispatch
    # order — exactly where the stable sort's pseudo-segment puts them).
    inst_row = (rr + pos) % k
    spos = jnp.where(valid, offsets[inst_row] + pos // k, pos)
    rank_row = jnp.where(valid, pos // k, pos - n_valid)
    key_row = jnp.where(valid, inst_row, jnp.int32(k))
    page = jnp.stack([idx, rank_row, key_row], axis=-1)
    s = jnp.zeros((n, 3), jnp.int32).at[spos].set(page, mode="drop")
    order, rank, s_inst = s[:, 0], s[:, 1], s[:, 2]
    head = rank == 0

    completion, new_busy = _sorted_batch_core(
        busy_init, arrival[order], s_inst, valid[order], head, rank,
        order, ssd,
    )
    return completion, new_busy, (rr + n_valid) % k


def aggregated_update(
    state: TimingState,
    batch: RequestBatch,
    ssd: SSDConfig,
    use_compaction: bool = False,
) -> Tuple[TimingState, jax.Array]:
    """SwarmIO aggregated timing update (single shared-state write)."""
    if use_compaction and ssd.routing == "round_robin":
        completion, new_busy, rr = compact_rr_batch_times(
            state.busy_until, batch.arrival, state.rr, batch.valid, ssd
        )
        return TimingState(new_busy, rr), completion
    inst, rr = assign_instances(state, batch, ssd)
    completion, new_busy = aggregated_batch_times(
        state.busy_until, batch.arrival, inst, batch.valid, ssd
    )
    return TimingState(new_busy, rr), completion


def local_scope_update(
    state: TimingState,
    arrival: jax.Array,     # (N,) f32, N % num_units == 0, unit-major
    valid: jax.Array,       # (N,) bool
    ssd: SSDConfig,
    num_units: int,
    use_compaction: bool = False,
) -> Tuple[TimingState, jax.Array]:
    """Paper's rejected design (§IV-D ablation): per-unit timing state.

    Each service unit owns a 1/U slice of the device's scheduling instances
    and capacity, so skewed load caps at 1/U of the target. Rows must be
    unit-major with equal counts per unit. Returns (state', completion).
    """
    u = num_units
    k_u = max(ssd.n_instances // u, 1)
    local_ssd = ssd.replace(t_max_iops=ssd.t_max_iops / u, n_instances=k_u)
    bu = state.busy_until.reshape(u, -1)
    rr_u = jnp.broadcast_to(state.rr, (u,))

    def per_unit(bu_u, rr_1, val_u, arr_u):
        if use_compaction and ssd.routing == "round_robin":
            comp, nb, rr_2 = compact_rr_batch_times(
                bu_u, arr_u, rr_1, val_u, local_ssd
            )
            return nb, rr_2, comp
        inst_u, rr_2 = assign_rr(rr_1, val_u, k_u)
        comp, nb = aggregated_batch_times(
            bu_u, arr_u, inst_u, val_u, local_ssd
        )
        return nb, rr_2, comp

    nb, rr_new, comp = jax.vmap(per_unit)(
        bu, rr_u, valid.reshape(u, -1), arrival.reshape(u, -1)
    )
    return TimingState(nb.reshape(-1), rr_new[0]), comp.reshape(-1)


# ---------------------------------------------------------------------------
# Distributed global timing model (one collective per batch).
# ---------------------------------------------------------------------------

def distributed_aggregated_update(
    state: TimingState,
    batch: RequestBatch,
    ssd: SSDConfig,
    axis_name: str,
) -> Tuple[TimingState, jax.Array]:
    """Global timing model across service units inside ``shard_map``.

    Each shard contributes its local batch; descriptors (arrival, valid) are
    all-gathered once per batch (the paper's single critical section), every
    shard runs the identical replicated segmented scan over the concatenated
    global batch (dispatch order = unit-major, preserving per-SQ order), and
    keeps its own slice of completions. ``state`` is replicated and evolves
    identically on every shard.
    """
    ax = jax.lax.axis_index(axis_name)
    n_units = jax.lax.axis_size(axis_name)
    n_local = batch.arrival.shape[0]

    g_arr = jax.lax.all_gather(batch.arrival, axis_name).reshape(-1)
    g_lba = jax.lax.all_gather(batch.lba, axis_name).reshape(-1)
    g_valid = jax.lax.all_gather(batch.valid, axis_name).reshape(-1)
    g_batch = RequestBatch(
        arrival=g_arr,
        sq_id=jnp.zeros_like(g_lba), slot=jnp.zeros_like(g_lba),
        opcode=jnp.zeros_like(g_lba), lba=g_lba,
        nblocks=jnp.ones_like(g_lba), buf_id=jnp.zeros_like(g_lba),
        req_id=jnp.zeros_like(g_lba), valid=g_valid,
    )
    inst, rr = assign_instances(state, g_batch, ssd)
    completion, new_busy = aggregated_batch_times(
        state.busy_until, g_arr, inst, g_valid, ssd
    )
    local = jax.lax.dynamic_slice_in_dim(completion, ax * n_local, n_local)
    return TimingState(new_busy, rr), local


def update(
    state: TimingState,
    batch: RequestBatch,
    ssd: SSDConfig,
    mode: str = "aggregated",
    axis_name: str | None = None,
    use_compaction: bool = False,
    dispatch_order: jax.Array | None = None,
) -> Tuple[TimingState, jax.Array]:
    """Dispatch to the configured update mechanism.

    ``use_compaction`` routes round-robin aggregated updates through the
    sort-free compacted form (``compact_rr_batch_times``); every other
    mode/routing combination falls back to its reference path.

    ``dispatch_order`` (PR 9, the ready-time lock) is an optional (N,)
    row permutation giving the order requests enter the shared timing
    state — position j of the permuted stream is original row
    ``dispatch_order[j]``. The batch is physically gathered through it,
    priced by the unchanged reference paths (round-robin assignment,
    busy-cursor recurrence, and the sort/compaction plans all key off
    the *permuted* stream — the ready-time keys thread through
    ``_sorted_batch_core``/``compact_rr_batch_times`` as pure layout),
    and completions scatter back to original row order. Gather + scatter
    only: the float expression tree is the verbatim reference one, so a
    monotone (identity) order is bit-exact with ``None`` and the PR-8
    FMA-contraction hazard cannot arise. ``None`` skips the permutation
    entirely (the program-order fast path — zero added ops).
    """
    if dispatch_order is not None:
        d = dispatch_order
        permuted = dataclasses.replace(
            batch,
            arrival=batch.arrival[d],
            lba=batch.lba[d],
            valid=batch.valid[d],
        )
        state, comp_p = update(
            state, permuted, ssd, mode, axis_name, use_compaction
        )
        return state, jnp.zeros_like(comp_p).at[d].set(comp_p, mode="drop")
    if axis_name is not None and mode == "aggregated":
        return distributed_aggregated_update(state, batch, ssd, axis_name)
    if mode == "per_request":
        return per_request_update(state, batch, ssd)
    if mode == "aggregated":
        return aggregated_update(state, batch, ssd, use_compaction)
    raise ValueError(f"unknown timing mode: {mode}")
