"""Core data types for the SwarmIO-JAX emulation engine.

Everything is struct-of-arrays so batches of requests stay vectorizable
inside jit. Virtual time is float32 *microseconds* (resolution ~0.06 us at
1e6 us — far below the 50 us device latencies we model).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

# NVMe-ish opcodes.
OP_READ = 0
OP_WRITE = 1

# Sentinel for "no request" slots in fixed-capacity batches.
INVALID = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RequestBatch:
    """A fixed-capacity batch of I/O requests (struct of arrays).

    ``valid`` masks live entries; invalid rows carry arbitrary payloads and
    must never influence timing state or the data path.
    """

    arrival: jax.Array   # (N,) f32 — virtual submission time (us)
    sq_id: jax.Array     # (N,) i32 — submission queue the request came from
    slot: jax.Array      # (N,) i32 — slot index within the SQ ring
    opcode: jax.Array    # (N,) i32 — OP_READ / OP_WRITE
    lba: jax.Array       # (N,) i32 — logical block address
    nblocks: jax.Array   # (N,) i32 — blocks per request (>=1)
    buf_id: jax.Array    # (N,) i32 — destination/source I/O buffer row
    req_id: jax.Array    # (N,) i32 — globally unique request id
    valid: jax.Array     # (N,) bool
    # Tenant (QoS) class per request. ``None`` (the default, kept by legacy
    # constructors) means "everything is tenant 0" — the fabric's WFQ
    # arbiter and the per-tenant metrics treat it as a single class.
    tenant: "jax.Array | None" = None  # (N,) i32 tenant/QoS class

    @property
    def capacity(self) -> int:
        return self.arrival.shape[0]

    @property
    def tenants(self) -> jax.Array:
        """Tenant ids with the ``None`` default lowered to all-zero."""
        if self.tenant is None:
            return jnp.zeros_like(self.sq_id)
        return self.tenant

    @staticmethod
    def empty(n: int) -> "RequestBatch":
        z = jnp.zeros((n,), jnp.int32)
        return RequestBatch(
            arrival=jnp.zeros((n,), jnp.float32),
            sq_id=z, slot=z, opcode=z, lba=z,
            nblocks=jnp.ones((n,), jnp.int32),
            buf_id=z, req_id=z,
            valid=jnp.zeros((n,), bool),
            tenant=z,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StorageOps:
    """A flat batch of storage operations for ``StorageClient.submit``.

    The unified client op descriptor: one slot per operation, carrying
    everything the rings -> pipeline -> CQ path needs — opcode, block
    address, QoS tenant class, and the virtual submission clock. Every
    legacy ``StorageClient`` entry point (``read``/``write``/
    ``read_array``/``write_array``/``read_striped``/``read_replicated``)
    lowers to one of these and goes through the single ``submit``
    implementation. Build batches with ``StorageOps.make`` (broadcasts
    scalars) rather than the raw constructor.

    ``valid`` masks live slots; invalid slots never touch the rings, the
    cache, or the device, and their payload fields are arbitrary.
    """

    opcode: jax.Array    # (N,) i32 — OP_READ / OP_WRITE
    lba: jax.Array       # (N,) i32 — logical block address
    t_submit: jax.Array  # (N,) f32 — virtual submission clock (us)
    tenant: jax.Array    # (N,) i32 — QoS class (fabric WFQ arbiter)
    valid: jax.Array     # (N,) bool

    @property
    def capacity(self) -> int:
        return self.lba.shape[0]

    @staticmethod
    def make(
        lba: jax.Array,
        t_submit: "jax.Array | float" = 0.0,
        opcode: "jax.Array | int" = OP_READ,
        tenant: "jax.Array | int" = 0,
        valid: jax.Array | None = None,
    ) -> "StorageOps":
        """Broadcasting constructor: scalars fan out to ``lba``'s shape.

        Works for flat (N,) batches and per-device (M, N) array batches
        alike (everything broadcasts against ``lba`` by numpy rules).
        """
        lba = jnp.asarray(lba, jnp.int32)
        shape = lba.shape
        if valid is None:
            valid = jnp.ones(shape, bool)
        return StorageOps(
            opcode=jnp.broadcast_to(jnp.asarray(opcode, jnp.int32), shape),
            lba=lba,
            t_submit=jnp.broadcast_to(
                jnp.asarray(t_submit, jnp.float32), shape
            ),
            tenant=jnp.broadcast_to(jnp.asarray(tenant, jnp.int32), shape),
            valid=valid,
        )

    def concat(self, other: "StorageOps") -> "StorageOps":
        """Concatenate two op batches (e.g. faults + write-backs)."""
        return jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), self, other
        )


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """Target-device model parameters (NVMeVirt simple timing model).

    ``t_max_iops`` is the sustained random-read ceiling; ``l_min_us`` the
    latency floor. ``n_instances`` abstracts flash channels/controllers: each
    request occupies one instance for ``sched_us = n_instances / t_max_iops``
    seconds of virtual time, so aggregate throughput saturates at t_max.
    """

    name: str = "solidigm-d7-ps1010"
    t_max_iops: float = 2.47e6
    l_min_us: float = 50.0
    n_instances: int = 64
    block_bytes: int = 512
    num_blocks: int = 1 << 20          # emulated flash capacity in blocks
    # Request->instance assignment. "round_robin" follows NVMeVirt/SwarmIO
    # semantics (paper §IV-D: "requests are assigned to scheduling instances
    # in the order in which they appear in the SQ") and perfectly load-
    # balances; "lba_hash" models channel striping by address (exposes
    # hash-imbalance idle time, used in sensitivity studies).
    routing: str = "round_robin"
    # --- Flash backend (pipeline stage 4, flash.py). The simple timing
    # model above already prices the *calibrated read path* (sched/l_min);
    # the flash backend adds the internals that model leaves out: program
    # latency and per-chip serialization for writes, greedy GC stealing
    # chip time when the free pool drains, and cached-mapping-table (CMT)
    # misses that cost an extra translation-page read. With
    # ``mapping_hit_rate=1.0`` and no writes the stage is an exact no-op,
    # so read-only workloads reproduce the 3-stage pipeline bit-exactly.
    flash_backend: bool = True
    num_channels: int = 8              # C — flash channels
    chips_per_channel: int = 4         # W — chips (dies) per channel
    flash_read_us: float = 40.0        # page (translation) read latency
    flash_program_us: float = 200.0    # page program latency
    flash_erase_us: float = 1000.0     # block erase latency
    pages_per_block: int = 64          # pages migrated/freed per GC victim
    over_provision: float = 0.07       # physical spare-capacity fraction
    gc_watermark: float = 0.02         # free-page fraction triggering GC
                                       # (<= 0 disables GC entirely)
    mapping_hit_rate: float = 1.0      # CMT hit probability (1.0 = cached)
    preconditioned: bool = False       # start fully written (steady state)

    def __post_init__(self) -> None:
        if self.num_channels < 1 or self.chips_per_channel < 1:
            raise ValueError(
                f"num_channels={self.num_channels} and chips_per_channel="
                f"{self.chips_per_channel} must be >= 1"
            )
        if not 0.0 <= self.mapping_hit_rate <= 1.0:
            raise ValueError(
                f"mapping_hit_rate={self.mapping_hit_rate} must be in [0, 1]"
            )
        if self.over_provision <= 0.0:
            raise ValueError(
                f"over_provision={self.over_provision} must be > 0 — with no "
                "spare capacity every write immediately deadlocks on GC"
            )
        if self.gc_watermark >= self.over_provision / (
            1.0 + self.over_provision
        ):
            raise ValueError(
                f"gc_watermark={self.gc_watermark} must be below the "
                f"over-provisioned free fraction "
                f"{self.over_provision / (1.0 + self.over_provision):.4f} — "
                "a fresh drive would start below its own GC trigger"
            )

    @property
    def sched_us(self) -> float:
        return self.n_instances / self.t_max_iops * 1e6

    @property
    def num_chips(self) -> int:
        """Total flash dies = channels x chips/channel."""
        return self.num_channels * self.chips_per_channel

    @property
    def phys_pages(self) -> float:
        """Physical page count including over-provisioned spare area."""
        return self.num_blocks * (1.0 + self.over_provision)

    def replace(self, **kw: Any) -> "SSDConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class PlatformModel:
    """Virtual-time cost model of the *emulator platform itself*.

    The paper evaluates two things: how faithful the emulated SSD timing is,
    and whether the emulator machinery can keep up with the request stream.
    We model the machinery costs explicitly in virtual time so the baseline's
    pathologies (fetch serialization, per-request map/unmap, per-request lock
    contention) reproduce the paper's Figs. 3-5/11/13/14, while wall-clock
    benchmarks separately measure the engine's real throughput.
    """

    sqe_bytes: int = 64
    # --- Fetch path (control path). Calibrated to the paper's Fig. 13
    # ablation: CPU p2p reads of GPU-resident SQEs are uncached MMIO-class
    # accesses (~10us per 64B line; coalesced streams amortize software but
    # still pay per-line), while DSA fetch is a sync offload (issue+poll)
    # whose cost is per-transaction, not per-line.
    cpu_sqe_fetch_us: float = 10.3      # per-SQE CPU p2p read
    cpu_coal_byte_us: float = 0.0268    # CPU coalesced p2p, per byte
    cpu_coal_base_us: float = 0.30
    dsa_sqe_fetch_us: float = 3.8       # sync DSA offload per 64B SQE
    dsa_coal_base_us: float = 18.0      # sync DSA offload, bulk txn setup
    # "host" transport models same-socket DRAM (fio CPU-centric baseline).
    host_txn_base_us: float = 0.05
    host_bytes_per_us: float = 80000.0
    # --- Data path. p2p link for CPU-thread copies:
    txn_base_us: float = 0.30
    link_bytes_per_us: float = 32000.0  # ~32 GB/s effective p2p
    # Baseline worker-side per-request map/unmap (memremap analogue, paper
    # Fig. 4 — 98.8% of copy latency). Page-table updates take *global*
    # kernel locks, so this cost is serialized across ALL workers.
    per_req_map_us: float = 2.90
    # DSA: per-descriptor issue cost, batch setup, engine bandwidth.
    dsa_desc_issue_us: float = 0.020
    dsa_batch_setup_us: float = 0.25
    dsa_bytes_per_us: float = 30000.0  # per-DSA-engine copy bandwidth
    # Timing-model shared-state critical section.
    lock_per_req_us: float = 0.085
    lock_per_batch_us: float = 0.40
    # Dispatcher fixed cost to poll one SQ doorbell.
    doorbell_poll_us: float = 0.02

    def replace(self, **kw: Any) -> "PlatformModel":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class QPConfig:
    """Queue-pair completion-side knobs (the CQ mirror of the SQ rings).

    The device *posts* completion entries to per-SQ completion queues and
    rings a CQ doorbell; the GPU consumer *polls* and *reaps* them. The
    defaults are neutral (no coalescing, zero posting/poll cost), so the
    completion path is virtual-time-transparent and reproduces the
    pre-QP pipeline bit-exactly — every knob only ever adds time.

    ``cq_coalesce_n``   completions batched per doorbell (1 = off)
    ``cq_coalesce_us``  timer bound: a partial batch flushes once its
                        oldest pending completion has waited this long
    ``cq_doorbell_us``  device-side cost to post one doorbell (serialized
                        per CQ — the completion-path analogue of the
                        fetch path's per-transaction cost)
    ``cq_poll_us``      GPU poll-pass cost per reaped doorbell batch
    ``cqe_reap_us``     GPU per-CQE read cost within a reaped batch
    """

    cq_coalesce_n: int = 1
    cq_coalesce_us: float = 0.0
    cq_doorbell_us: float = 0.0
    cq_poll_us: float = 0.0
    cqe_reap_us: float = 0.0

    def __post_init__(self) -> None:
        if self.cq_coalesce_n < 1:
            raise ValueError(
                f"cq_coalesce_n={self.cq_coalesce_n} must be >= 1"
            )
        for name in (
            "cq_coalesce_us", "cq_doorbell_us", "cq_poll_us", "cqe_reap_us"
        ):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0")

    @property
    def neutral(self) -> bool:
        """True iff the completion path cannot change any virtual time."""
        return (
            self.cq_coalesce_n == 1
            and self.cq_coalesce_us == 0.0
            and self.cq_doorbell_us == 0.0
            and self.cq_poll_us == 0.0
            and self.cqe_reap_us == 0.0
        )

    def replace(self, **kw: Any) -> "QPConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """NIC/link hop between the GPU initiator and a *remote* drive.

    Disaggregated all-flash arrays reach their drives over a network
    fabric (NVMe-oF style): submitted SQEs (plus write payloads) cross
    the wire to the target, and completions (plus read payloads) cross
    back. The hop is priced per direction on a single serialized link
    cursor per drive — an M-drive remote array vmaps the pipeline, so
    each drive gets its own link — in the same epoch-batched style as
    the CQ layer (qp.py). With ``remote=False`` (the default) the stage
    is skipped entirely, so local-drive pipelines reproduce bit-exactly.

    ``remote``          model the fabric hop at all (False = local drive)
    ``rtt_us``          round-trip propagation; each direction pays half
    ``tx_bytes_per_us`` initiator->target link bandwidth (SQEs + write
                        payloads); ``inf`` = unconstrained
    ``rx_bytes_per_us`` target->initiator link bandwidth (CQEs + read
                        payloads); ``inf`` = unconstrained
    ``wire_txn_us``     per-wire-transaction setup (NIC doorbell/DMA
                        descriptor), charged once per MTU batch
    ``mtu_batch``       SQE/CQE frames packed per wire transaction
                        (1 = every frame is its own transaction)
    ``mtu_timeout_us``  flush bound: a partial MTU batch ships once its
                        oldest frame has waited this long
    ``cqe_bytes``       completion-entry size on the wire

    **Shared switch / initiator NIC.** The per-drive links of an M-drive
    remote array converge on one switch (incast): frames additionally
    serialize through a switch-port cursor whose per-link share is
    ``switch_bytes_per_us / switch_fanin`` in each direction. Set
    ``switch_fanin=M`` so the M vmapped lanes split the aggregate roof
    fairly (the epoch-batched fair-share port model — exact for the
    symmetric saturated regime the roofline figures measure). ``inf``
    (the default) disables the stage entirely.

    ``switch_bytes_per_us``  aggregate switch roof per direction
    ``switch_fanin``         links sharing the switch (M for an array)

    **Per-tenant QoS.** ``qos_weights`` holds one weighted-fair-queueing
    weight per tenant class; requests carry a tenant id
    (``RequestBatch.tenant``) and every shared fabric resource (link and
    switch) serves backlogged tenants in weighted virtual-finish order,
    so tenant k's saturated share tracks ``w_k / sum(w)``. Empty (the
    default) means a single class — the arbiter is skipped and the hop
    is bit-exact with the unweighted PR-4 path.
    """

    remote: bool = False
    rtt_us: float = 0.0
    tx_bytes_per_us: float = float("inf")
    rx_bytes_per_us: float = float("inf")
    wire_txn_us: float = 0.0
    mtu_batch: int = 1
    mtu_timeout_us: float = 0.0
    cqe_bytes: int = 16
    switch_bytes_per_us: float = float("inf")
    switch_fanin: int = 1
    qos_weights: tuple = ()

    def __post_init__(self) -> None:
        if self.mtu_batch < 1:
            raise ValueError(f"mtu_batch={self.mtu_batch} must be >= 1")
        if self.tx_bytes_per_us <= 0.0 or self.rx_bytes_per_us <= 0.0:
            raise ValueError(
                "tx_bytes_per_us and rx_bytes_per_us must be > 0 "
                "(use inf for an unconstrained link)"
            )
        if self.switch_bytes_per_us <= 0.0:
            raise ValueError(
                "switch_bytes_per_us must be > 0 "
                "(use inf for an unconstrained switch)"
            )
        if self.switch_fanin < 1:
            raise ValueError(
                f"switch_fanin={self.switch_fanin} must be >= 1"
            )
        if any(w <= 0.0 for w in self.qos_weights):
            raise ValueError(
                f"qos_weights={self.qos_weights} must all be > 0 — a "
                "zero-weight tenant would never be scheduled"
            )
        if self.cqe_bytes < 1:
            raise ValueError(f"cqe_bytes={self.cqe_bytes} must be >= 1")
        for name in ("rtt_us", "wire_txn_us", "mtu_timeout_us"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0")

    @property
    def num_tenants(self) -> int:
        """Tenant classes the WFQ arbiter distinguishes (1 = off)."""
        return max(1, len(self.qos_weights))

    @property
    def switched(self) -> bool:
        """True iff the shared-switch stage prices anything at all."""
        return self.remote and math.isfinite(self.switch_bytes_per_us)

    @property
    def switch_share_bytes_per_us(self) -> float:
        """One link's fair share of the aggregate switch roof."""
        return self.switch_bytes_per_us / self.switch_fanin

    @property
    def neutral(self) -> bool:
        """True iff the hop cannot change any virtual time: a local
        drive, or a remote one behind a zero-cost wire (unconstrained
        both ways, zero RTT/txn cost, no MTU batching delay —
        ``mtu_batch > 1`` still holds early frames for the batch flush
        unless the timeout is zero — and an unconstrained switch).
        ``qos_weights`` alone never break neutrality: reordering
        zero-cost frames cannot move any landing time."""
        return (not self.remote) or (
            self.rtt_us == 0.0
            and self.wire_txn_us == 0.0
            and math.isinf(self.tx_bytes_per_us)
            and math.isinf(self.rx_bytes_per_us)
            and math.isinf(self.switch_bytes_per_us)
            and (self.mtu_batch == 1 or self.mtu_timeout_us == 0.0)
        )

    def replace(self, **kw: Any) -> "FabricConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """GPU-side set-associative page cache (pipeline stage 0).

    Hits are filtered *before* SQ submission: they complete at
    ``hit_us`` of GPU-local latency and never consume ring slots,
    frontend transactions, or device time. ``chase`` bounds how many
    consecutive hits one closed-loop slot may chain within a single
    engine round (each hit immediately proposes the slot's next request,
    which may hit again). ``readahead`` inserts the next R sequential
    blocks alongside every miss fill.
    """

    enabled: bool = False
    num_sets: int = 512
    ways: int = 4
    hit_us: float = 0.5
    chase: int = 2
    readahead: int = 0

    def __post_init__(self) -> None:
        if self.num_sets < 1 or self.ways < 1:
            raise ValueError(
                f"num_sets={self.num_sets} and ways={self.ways} must be >= 1"
            )
        if self.chase < 1:
            raise ValueError(f"chase={self.chase} must be >= 1")
        if self.hit_us < 0.0 or self.readahead < 0:
            raise ValueError("hit_us and readahead must be >= 0")

    @property
    def capacity(self) -> int:
        return self.num_sets * self.ways

    def replace(self, **kw: Any) -> "CacheConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Closed-loop synthetic workload (fio / BaM analogue)."""

    io_depth: int = 64                # outstanding requests per SQ
    read_frac: float = 1.0            # fraction of reads
    resubmit_delay_us: float = 1.0    # client think time after completion
    seed: int = 0

    def replace(self, **kw: Any) -> "WorkloadConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Emulation-engine shape parameters (compile-time constants)."""

    num_sqs: int = 32                 # submission queues
    sq_depth: int = 1024              # ring entries per SQ
    fetch_width: int = 64             # coalesced fetch: max entries/SQ/round
    num_units: int = 1                # service units (shards of SQs)
    workers_per_unit: int = 1         # backend copy pipelines per unit
    num_bufs: int = 1 << 15           # I/O buffer rows (block-sized)
    mode: str = "aggregated"          # "aggregated" | "per_request"
    frontend: str = "distributed"     # "distributed" | "centralized"
    coalesced: bool = True            # coalesced fetching  (C in Fig. 13)
    dsa_fetch: bool = True            # DSA-accelerated fetch (A in Fig. 13)
    batched_datapath: bool = True     # DSA worker-side data path
    timing_scope: str = "global"      # "global" | "local" (§IV-D ablation)
    transport: str = "p2p"            # "p2p" (GPU-initiated) | "host"
    poll_quantum_us: float = 10.0     # virtual-time window batched per round
    emulate_data: bool = True         # perform functional block copies
    use_pallas: bool = False          # Pallas kernels (TPU) vs jnp reference
    # Wall-clock hot-path knobs (virtual time is identical either way):
    # ``use_sort_plan`` builds one epoch sort plan per key in
    # ``DevicePipeline.process`` and shares it across the stages that
    # segment the same batch (datapath unit ranks, the CQ posting rank,
    # the fused fabric/CQ time-major frame sorts) instead of re-sorting
    # per stage — bit-exact by construction, parity-tested in
    # tests/test_segops.py. ``use_compaction`` (PR 8, default on like
    # ``use_sort_plan``) switches the hot stages to epoch-compacted /
    # counting-sort / fused-scatter forms — dense round-robin timing
    # (``timing.compact_rr_batch_times``), counting-sorted flash die
    # contention, block-wise CQ ranks, and stacked one-pass ring
    # scatters — all proven bit-exact in virtual time and pinned by
    # full-run parity tests (tests/test_emulator_speed.py).
    # ``use_pallas_segscan`` routes the ``segops.queueing_scan`` (max,+)
    # core through the ``kernels/seg_scan`` Pallas kernel. ``None`` (the
    # default) auto-resolves per pipeline via
    # ``resolve_pallas_segscan``: on iff ``integer_timestamps`` proves
    # every config-derived virtual-time cost is an integer number of
    # microseconds — the bit-exactness precondition PR 6 established for
    # the kernel's prefix-max reduction (integer-valued f32 sums are
    # exact under any association). Fallback note: with any fractional
    # cost in the model (the default PlatformModel has several) the
    # auto check fails closed and the ``lax.associative_scan`` reference
    # path runs; pass an explicit ``True``/``False`` to override —
    # explicit ``False`` is the safe choice when driving fractional
    # arrival processes (e.g. Poisson open loop) on an otherwise
    # integer-costed platform, which the static check cannot see.
    use_sort_plan: bool = True
    use_compaction: bool = True
    use_pallas_segscan: "bool | None" = None
    # Global timing-lock acquisition order (stage 2a, device.acquire_lock).
    # "program" (the default) serializes service units in their unit-loop
    # index order — the NVMeVirt/SwarmIO behavior every earlier PR pinned
    # bit-exactly. "ready_time" grants the lock in order of each unit's
    # epoch *ready time* (the post-fabric-TX arrival of its batch at the
    # device, ties broken by unit index), and dispatches the timing model
    # in the same acquisition order — so a bulk tenant's late wire tail
    # no longer holds the lock in front of an earlier-ready latency
    # tenant's unit (true cross-tenant isolation on misaligned tenant
    # mixes; see workloads.MultiTenant(interleave=True) and fig29).
    # Whenever ready times are already monotone in program order the two
    # orders coincide bit-exactly (property-tested). No effect under
    # timing_scope="local" (there is no shared lock to order).
    lock_order: str = "program"
    # Fused Pallas stage kernels (kernels/ops/): a one-pass
    # post-and-reap ring layout (``fused_reap``) and a sequential flash
    # die-contention fold (``die_contention``). Off by default — the lax
    # paths are the reference; both kernels are TPU-targeted (interpret
    # mode on CPU) and parity-tested in tests/test_segops.py.
    use_pallas_reap: bool = False
    use_pallas_flash: bool = False
    # Runtime sanitizer (PR 10): threads jax.experimental.checkify
    # assertions through ``DevicePipeline.process`` — ring scatter/
    # gather indices in bounds, completion times monotone non-negative,
    # valid-mask conservation across the compaction/admission
    # permutations, flash free-page and fabric cursor non-negativity.
    # The checks only *observe* (no data-path op changes), so a
    # sanitized run's state is bit-exact with the default run; off by
    # default because checkify functionalization rewrites the jit
    # program (wall-clock cost) and requires the checkified entry
    # points (``engine.make_runner(..., sanitize=True)`` wraps and
    # ``err.throw()``s automatically; calling ``DevicePipeline.process``
    # under plain jit with sanitize on raises at trace time).
    sanitize: bool = False
    # Sub-configs (split out rather than growing this class flat):
    qp: QPConfig = QPConfig()         # completion-side (CQ) model
    cache: CacheConfig = CacheConfig()  # GPU-side page cache (stage 0)
    fabric: FabricConfig = FabricConfig()  # NIC/link hop (remote drives)

    def __post_init__(self) -> None:
        if self.num_sqs < 1 or self.sq_depth < 1:
            raise ValueError(
                f"num_sqs={self.num_sqs} and sq_depth={self.sq_depth} "
                "must be >= 1"
            )
        if self.num_units < 1 or self.workers_per_unit < 1:
            raise ValueError(
                f"num_units={self.num_units} and workers_per_unit="
                f"{self.workers_per_unit} must be >= 1"
            )
        if self.fetch_width < 1 or self.fetch_width > self.sq_depth:
            raise ValueError(
                f"fetch_width={self.fetch_width} must be in "
                f"[1, sq_depth={self.sq_depth}] — a dispatcher cannot fetch "
                "more entries than a ring holds"
            )
        if self.frontend not in ("distributed", "centralized"):
            raise ValueError(f"unknown frontend: {self.frontend!r}")
        if self.mode not in ("aggregated", "per_request"):
            raise ValueError(f"unknown timing mode: {self.mode!r}")
        if self.timing_scope not in ("global", "local"):
            raise ValueError(f"unknown timing_scope: {self.timing_scope!r}")
        if self.lock_order not in ("program", "ready_time"):
            raise ValueError(f"unknown lock_order: {self.lock_order!r}")
        if self.transport not in ("p2p", "host"):
            raise ValueError(f"unknown transport: {self.transport!r}")
        units = self.num_units if self.frontend == "distributed" else 1
        if self.num_sqs % units != 0:
            raise ValueError(
                f"num_sqs={self.num_sqs} must be divisible by num_units="
                f"{units} — SQs are statically partitioned across service "
                "units (a remainder would silently mis-shape the fetch batch)"
            )

    def replace(self, **kw: Any) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    def resolve_pallas_segscan(
        self, ssd: "SSDConfig", plat: "PlatformModel"
    ) -> bool:
        """Resolve the ``use_pallas_segscan`` auto default (``None``).

        Explicit ``True``/``False`` wins; ``None`` resolves to the
        ``integer_timestamps`` static proof that the Pallas reduction is
        bit-exact for this (cfg, ssd, plat) triple. See the field
        docstring for the fractional-arrival fallback note.
        """
        if self.use_pallas_segscan is not None:
            return self.use_pallas_segscan
        return integer_timestamps(self, ssd, plat)


def integer_timestamps(
    cfg: "EngineConfig", ssd: "SSDConfig", plat: "PlatformModel"
) -> bool:
    """True iff every config-derived virtual-time cost is integer-valued.

    The static bit-exactness precondition for the Pallas segmented-scan
    reduction (``queueing_scan_via_segmax``): integer-valued f32 sums
    below 2^24 are exact under *any* association, so re-associating the
    cost cumsum cannot diverge from the reference scan. The check is
    deliberately conservative (False negatives are fine — the reference
    path is always correct): it requires every microsecond cost the
    engine can derive from (cfg, ssd, plat) to be a whole number, every
    wire/link byte-rate to divide its integer byte counts exactly (or be
    ``inf``, a zero cost), and bails on model paths with fractional
    hard-coded constants (the DSA batched datapath) or non-trivial GPS
    weight ratios (multi-tenant QoS).
    """

    def ints(*vals: float) -> bool:
        return all(float(v).is_integer() for v in vals)

    def div_ok(nbytes: float, bw: float) -> bool:
        return math.isinf(bw) or (float(nbytes) / bw).is_integer()

    if cfg.batched_datapath:
        return False  # dsa_worker_times carries fractional constants
    if not ints(
        plat.cpu_sqe_fetch_us, plat.cpu_coal_byte_us, plat.cpu_coal_base_us,
        plat.dsa_sqe_fetch_us, plat.dsa_coal_base_us, plat.host_txn_base_us,
        plat.txn_base_us, plat.per_req_map_us, plat.dsa_desc_issue_us,
        plat.dsa_batch_setup_us, plat.lock_per_req_us, plat.lock_per_batch_us,
        plat.doorbell_poll_us, cfg.poll_quantum_us,
    ):
        return False
    if not (
        div_ok(ssd.block_bytes, plat.link_bytes_per_us)
        and div_ok(ssd.block_bytes, plat.host_bytes_per_us)
        and div_ok(ssd.block_bytes, plat.dsa_bytes_per_us)
        and div_ok(plat.sqe_bytes, plat.host_bytes_per_us)
    ):
        return False
    if not ints(ssd.sched_us, ssd.l_min_us):
        return False
    if ssd.flash_backend and not ints(
        ssd.flash_read_us, ssd.flash_program_us, ssd.flash_erase_us
    ):
        return False
    if cfg.cache.enabled and not ints(cfg.cache.hit_us):
        return False
    if not ints(
        cfg.qp.cq_coalesce_us, cfg.qp.cq_doorbell_us,
        cfg.qp.cq_poll_us, cfg.qp.cqe_reap_us,
    ):
        return False
    fab = cfg.fabric
    if fab.remote:
        if fab.num_tenants > 1:
            return False  # GPS weight ratios inflate costs fractionally
        if not ints(0.5 * fab.rtt_us, fab.wire_txn_us, fab.mtu_timeout_us):
            return False
        if not (
            div_ok(plat.sqe_bytes, fab.tx_bytes_per_us)
            and div_ok(ssd.block_bytes, fab.tx_bytes_per_us)
            and div_ok(fab.cqe_bytes, fab.rx_bytes_per_us)
            and div_ok(ssd.block_bytes, fab.rx_bytes_per_us)
        ):
            return False
        if fab.switched and not (
            div_ok(plat.sqe_bytes, fab.switch_share_bytes_per_us)
            and div_ok(ssd.block_bytes, fab.switch_share_bytes_per_us)
            and div_ok(fab.cqe_bytes, fab.switch_share_bytes_per_us)
        ):
            return False
    return True


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TimingState:
    """Shared timing-model state: per-scheduling-instance busy-until times
    plus the round-robin assignment cursor (dispatch-order routing)."""

    busy_until: jax.Array  # (K,) f32 virtual us
    rr: jax.Array          # ()  i32 next instance for round-robin routing

    @staticmethod
    def init(n_instances: int) -> "TimingState":
        return TimingState(
            busy_until=jnp.zeros((n_instances,), jnp.float32),
            rr=jnp.int32(0),
        )
