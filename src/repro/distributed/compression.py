"""Gradient compression: int8 quantization with error feedback.

Used on the cross-pod (DCN-bound) gradient reduction: per-tensor-block
scales, int8 payload (4x smaller than f32), and a residual carried to the
next step so quantization error does not bias the optimizer (EF-SGD). The
compression is applied *around* the all-reduce: local grads + residual are
quantized, reduced in int8-space equivalent (here: dequantized sum — XLA
reduces in the compressed domain when lowered with the custom collective
schedule), and the residual keeps what quantization dropped.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (q (N/B, B) i8, scale (N/B, 1))."""
    flat, _ = _pad_to_block(g)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array, shape, n: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_leaf(
    g: jax.Array, residual: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """EF step for one tensor: returns (decompressed grad, new residual)."""
    if g.ndim == 0 or g.size < BLOCK:
        return g, residual  # tiny tensors ride uncompressed
    target = g.astype(jnp.float32) + residual
    q, s = quantize(target)
    deq = dequantize(q, s, g.shape, g.size)
    new_residual = target - deq
    return deq.astype(g.dtype), new_residual


def compress_tree(grads, residuals):
    """Apply EF-int8 compression across a gradient pytree."""
    out = jax.tree.map(compress_leaf, grads, residuals)
    return jax.tree.transpose(
        jax.tree.structure(grads), jax.tree.structure((0, 0)), out
    )


def init_residuals(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_bytes(params) -> int:
    """Wire bytes per step with int8 + per-block f32 scales."""
    total = 0
    for p in jax.tree.leaves(params):
        if p.size < BLOCK:
            total += p.size * 4
        else:
            nblk = -(-p.size // BLOCK)
            total += p.size + nblk * 4
    return total
