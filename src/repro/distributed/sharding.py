"""Logical-axis sharding: MaxText-style rules mapping model-space axis names
to mesh axes, applied through ``with_sharding_constraint`` hooks that are
no-ops outside a mesh context (so the same model code runs on one CPU device
and on a (pod, data, model) production mesh).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs, check_vma=False):
        """Version-portable shard_map (jax >= 0.6 top-level API)."""
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, mesh, in_specs, out_specs, check_vma=False):
        """Version-portable shard_map (jax < 0.6 experimental API; its
        ``check_rep`` flag plays the role of ``check_vma``)."""
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )

# Default production rules. None ⇒ replicated. An axis only binds when the
# dimension is divisible by the mesh extent (spec_for checks shapes), so
# e.g. MQA kv_heads=1 falls through and the kv_seq dim picks up "model".
DEFAULT_RULES: tuple[tuple[str, Any], ...] = (
    ("batch", ("pod", "data")),   # falls back to ("data",) on single-pod
    ("seq", "model"),             # sequence parallelism on the residual
    ("embed", "data"),            # FSDP dim of weight matrices
    ("heads", "model"),
    ("kv_heads", "model"),
    ("kv_seq", "model"),          # long KV caches when kv_heads can't shard
    ("head_dim", None),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),
    ("expert_mlp", None),
    ("expert_cap", "data"),       # MoE dispatch buffer rows follow tokens
    ("tokens", ("pod", "data", "model")),  # flattened (B*S) token dim
    ("lru", "model"),
    ("conv", None),
    ("layers", None),
)

_ctx = threading.local()


def _rules_dict(rules) -> dict:
    return dict(rules)


def _mesh_axes(mesh: Mesh) -> set:
    return set(mesh.axis_names)


def _resolve(logical: str, rules: dict, mesh: Mesh):
    """Logical axis -> mesh axis (or tuple), dropping absent mesh axes."""
    target = rules.get(logical)
    if target is None:
        return None
    axes = _mesh_axes(mesh)
    if isinstance(target, (tuple, list)):
        kept = tuple(t for t in target if t in axes)
        return kept if kept else None
    return target if target in axes else None


def spec_for(
    logical_axes: Sequence[str | None],
    rules,
    mesh: Mesh,
    shape: Sequence[int] | None = None,
) -> P:
    if tuple(logical_axes) == REPLICATED:
        return P()
    rd = _rules_dict(rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = []
    used: set = set()

    def extent(r) -> int:
        if isinstance(r, tuple):
            out = 1
            for x in r:
                out *= sizes[x]
            return out
        return sizes[r]

    def fit(r, dim: int | None):
        """Drop already-used axes; drop bindings the dim can't divide."""
        if r is None:
            return None
        if isinstance(r, tuple):
            kept = tuple(x for x in r if x not in used)
            if not kept:
                return None
            if dim is not None and dim % extent(kept) != 0:
                # Try each member axis alone (largest first).
                for x in sorted(kept, key=lambda x: -sizes[x]):
                    if dim % sizes[x] == 0:
                        used.add(x)
                        return x
                return None
            used.update(kept)
            return kept
        if r in used:
            return None
        if dim is not None and dim % extent(r) != 0:
            return None
        used.add(r)
        return r

    for i, ax in enumerate(logical_axes):
        r = None if ax is None else _resolve(ax, rd, mesh)
        dim = None if shape is None else shape[i]
        parts.append(fit(r, dim))
    return P(*parts)


# Sentinel axes for scalar/replicated leaves (a bare () would be
# indistinguishable from an empty *structural* tuple in a pytree).
REPLICATED = ("__replicated__",)


def _is_axes(x) -> bool:
    return (
        isinstance(x, tuple)
        and len(x) > 0
        and all(isinstance(e, (str, type(None))) for e in x)
    )


def sharding_tree(axes_tree, rules, mesh: Mesh, shapes_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings.

    ``shapes_tree`` (same structure; leaves with .shape, e.g. arrays or
    ShapeDtypeStructs) enables divisibility-aware binding.
    """
    if shapes_tree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, spec_for(ax, rules, mesh)),
            axes_tree, is_leaf=_is_axes,
        )
    flat_ax, treedef = jax.tree_util.tree_flatten(axes_tree, is_leaf=_is_axes)
    flat_shape = treedef.flatten_up_to(shapes_tree)
    out = [
        NamedSharding(mesh, spec_for(ax, rules, mesh, leaf.shape))
        for ax, leaf in zip(flat_ax, flat_shape)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def current_context():
    """(mesh, rules) if inside ``use_rules``, else None."""
    return getattr(_ctx, "state", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules=DEFAULT_RULES):
    """Activate logical constraints inside model code."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """Apply a logical sharding constraint if a rules context is active."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(logical, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
