"""DSA-analogue batched block copy as a Pallas TPU kernel.

The paper offloads emulated storage copies to Intel DSA via *batch
descriptors*: an array of (src, dst) copy descriptors issued at once, with
the engine pipelining the copies while the CPU does other work. The TPU
analogue: the descriptor array (block indices) is *scalar-prefetched* into
SMEM, each grid step DMAs one flash block HBM->VMEM->HBM, and Pallas's grid
pipeline double-buffers the DMAs across steps — the hardware overlap the
paper obtains from DSA's pipelined engines.

Blocks are (block_rows, width) tiles of a (num_blocks*block_rows, width)
flash array, so a 512-byte emulated sector maps to one (1, 128) f32 tile and
larger I/O sizes map to taller tiles; width stays lane-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, flash_ref, out_ref):
    # flash_ref is the BlockSpec-selected source tile (block_rows, width):
    # the index_map already routed the DMA using the prefetched descriptor,
    # so the body is a pure VMEM->VMEM move.
    out_ref[...] = flash_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gather(
    flash: jax.Array,   # (num_blocks, width)
    idx: jax.Array,     # (n,) i32 block indices ("copy descriptors")
    *,
    interpret: bool = True,
) -> jax.Array:
    """out[i] = flash[idx[i]] — one DMA'd block per descriptor."""
    n = idx.shape[0]
    num_blocks, width = flash.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, width), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, width), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, width), flash.dtype),
        interpret=interpret,
    )(idx, flash)


def _gather_tile_kernel(idx_ref, flash_ref, out_ref, *, tile: int):
    out_ref[...] = flash_ref[...]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def block_gather_tiled(
    flash: jax.Array,   # (num_blocks, width)
    idx: jax.Array,     # (n,) i32, n % tile == 0, idx pre-sorted in tiles of
                        # consecutive blocks is NOT required — each grid step
                        # still moves ``tile`` rows via one descriptor each.
    *,
    tile: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Gather with ``tile`` descriptors per grid step (larger batch size).

    Mirrors DSA batch descriptors of size ``tile``: the kernel loops over the
    tile's descriptors, each selecting a dynamic flash row. Rows are loaded
    with dynamic slices inside the kernel (VMEM-resident flash panel), so
    this variant requires flash small enough to tile by rows; the plain
    ``block_gather`` handles arbitrarily large flash.
    """
    n = idx.shape[0]
    assert n % tile == 0, "descriptor count must be a multiple of tile"
    num_blocks, width = flash.shape

    def kernel(idx_ref, flash_ref, out_ref):
        def body(j, _):
            row = idx_ref[j]
            out_ref[j, :] = flash_ref[row, :]
            return 0

        jax.lax.fori_loop(0, tile, body, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((num_blocks, width), lambda i, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, width), lambda i, idx_ref: (i, 0)),
    )

    def kernel_slice(idx_ref, flash_ref, out_ref):
        base = pl.program_id(0) * tile

        def body(j, _):
            row = idx_ref[base + j]
            out_ref[j, :] = flash_ref[row, :]
            return 0

        jax.lax.fori_loop(0, tile, body, 0)

    return pl.pallas_call(
        kernel_slice,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, width), flash.dtype),
        interpret=interpret,
    )(idx, flash)
