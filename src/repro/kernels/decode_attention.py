"""Flash-decoding Pallas kernel: one new token vs. a long KV cache.

This is the serve-side hot loop for the decode_32k / long_500k shapes: a
single query row per (batch, head) attends over the cache with an online
softmax across kv blocks. Cache lengths are scalar-prefetched so the kernel
masks (and skips) blocks past each sequence's length — with a 512k cache at
length 4k, ~99% of grid steps are skipped via ``pl.when``.

Sequence (KV) sharding for production meshes is layered on top in
models/attention.py: each shard runs this kernel over its cache slice and
the partial (m, l, acc) triples are combined with one ``psum`` — the
collective-efficient flash-decoding reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _decode_kernel(
    lengths_ref,  # scalar-prefetch (B,) i32
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    window: int | None,
    softcap: float | None,
    scale: float,
    block_k: int,
    num_k_blocks: int,
):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ik * block_k
    compute = k_start < length
    if window is not None:
        compute &= (k_start + block_k - 1) > (length - 1 - window)

    @pl.when(compute)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # (1, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = cols < length
        if window is not None:
            mask &= cols > length - 1 - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "window", "logit_softcap", "scale", "block_k", "interpret"
    ),
)
def decode_attention(
    q: jax.Array,        # (B, Hq, D)
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) i32
    *,
    window: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    nk = s // block_k
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _decode_kernel,
        window=window, softcap=logit_softcap, scale=scale_v,
        block_k=block_k, num_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, ik, L: (b_, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, ik, L: (b_, h // group, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, ik, L: (b_, h // group, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda b_, h, ik, L: (b_, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q[:, :, None, :], k_cache, v_cache)
    return out[:, :, 0, :]
