"""Per-die flash contention as a Pallas kernel (flash-stage hot path).

The flash stage (core/flash.py) queues each epoch's event rows (writes
and mapping misses) on their die: sort rows by die, run a segmented
queueing scan seeded by the epoch-start die cursors, scatter back, then
``segment_max`` the results into the new cursors. This kernel replaces
sort + scan + unsort + max with one sequential left fold over the rows
in dispatch order, carrying a (K,) busy-cursor vector in the output
ref: row i on die c observes ``b = max(cur[c], ready_i) + cost_i`` and
advances ``cur[c] = b``.

The fold evaluates the queueing recurrence literally, while the lax
reference re-associates it (a segmented max-plus scan) — the two agree
bit-exactly only when timestamps are integer-valued (the same contract
as ``use_pallas_segscan``; see ``types.integer_timestamps``). Events
only move cursors forward (cost > 0), so ``new_cursors >= chip_busy``
holds like the reference's outer ``maximum``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _die_contention_kernel(
    ready_ref, cost_ref, chip_ref, event_ref, cur_in, busy_ref, cur_out
):
    cur_out[...] = cur_in[...]
    busy_ref[...] = jnp.zeros_like(busy_ref)
    n = ready_ref.shape[1]

    def body(i, carry):
        @pl.when(event_ref[0, i] != 0)
        def _ev():
            c = chip_ref[0, i]
            b = jnp.maximum(cur_out[0, c], ready_ref[0, i]) + cost_ref[0, i]
            busy_ref[0, i] = b
            cur_out[0, c] = b

        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def die_contention(
    ready: jax.Array,      # (N,) f32 post-lock dispatch times
    cost: jax.Array,       # (N,) f32 die occupancy per event row
    chip: jax.Array,       # (N,) i32 die per row, pre-clipped to [0, K)
    event: jax.Array,      # (N,) bool rows that occupy their die
    chip_busy: jax.Array,  # (K,) f32 epoch-start die cursors
    *,
    interpret: bool = True,
):
    """Returns (busy, new_cursors): per-row die-service completion (0 for
    non-event rows — the flash stage never reads those) and the advanced
    (K,) cursors."""
    n = ready.shape[0]
    k = chip_busy.shape[0]
    busy, cur = pl.pallas_call(
        _die_contention_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(
        ready[None, :], cost[None, :], chip[None, :],
        event.astype(jnp.int32)[None, :], chip_busy[None, :],
    )
    return busy[0], cur[0]
