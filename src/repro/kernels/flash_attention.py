"""Blockwise (flash) attention Pallas TPU kernel.

Supports the whole assigned-arch zoo: causal masking, GQA (kv-head
grouping via BlockSpec index maps — KV blocks are never replicated in
VMEM), sliding-window local attention (gemma2 / recurrentgemma), and
attention-logit softcapping (gemma2).

Grid = (B, Hq, num_q_blocks, num_kv_blocks); the kv dimension is innermost
and executes sequentially on TPU, so the online-softmax running state
(m, l, acc) lives in VMEM scratch across kv steps. Fully-masked kv blocks
are skipped with ``pl.when`` (the causal/window block-level bound), which
is where the kernel beats a dense attention on long sequences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Block-level bounds: with causal masking, kv blocks strictly above the
    # diagonal contribute nothing; with a local window, kv blocks entirely
    # below (row - window) contribute nothing either.
    q_start = iq * block_q
    q_end = q_start + block_q - 1
    k_start = ik * block_k
    compute = jnp.bool_(True)
    if causal:
        compute &= k_start <= q_end
    if window is not None:
        compute &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(compute)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        cols = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]                                   # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_softcap", "scale",
        "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "Hq must be a multiple of Hkv (GQA)"
    group = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _fa_kernel,
        causal=causal, window=window, softcap=logit_softcap,
        scale=scale_v, block_q=block_q, block_k=block_k, num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, iq, ik: (b_, h // group, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, iq, ik: (b_, h // group, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
