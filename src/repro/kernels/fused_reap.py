"""Fused CQ post-and-reap as a Pallas kernel (neutral QP hot path).

The neutral completion path (core/qp.py) spends its time on bookkeeping,
not modeling: a per-CQ posting rank (``segment_rank`` — a stable sort),
three ring scatters, and a per-CQ ``segment_sum`` of valid entries. This
kernel fuses all of it into one sequential pass over the epoch's rows:
a (Q,) counter vector in the output ref *is* the rank, the ring slot,
and the count at once — row i of CQ c posts at ``(tail[c] + cnt[c]) % D``
and bumps ``cnt[c]``. Grid is a single step (the pass is inherently
sequential); everything is integer bookkeeping and data movement, so the
result is bit-exact against the reference for *any* inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_reap_kernel(
    dt_in, vt_in, rid_in, tail_ref, key_ref, done_ref, req_ref, valid_ref,
    dt_out, vt_out, rid_out, counts_ref, *, depth: int,
):
    dt_out[...] = dt_in[...]
    vt_out[...] = vt_in[...]
    rid_out[...] = rid_in[...]
    counts_ref[...] = jnp.zeros_like(counts_ref)
    n = key_ref.shape[1]

    def body(i, carry):
        @pl.when(valid_ref[0, i] != 0)
        def _post():
            c = key_ref[0, i]
            r = counts_ref[0, c]
            pos = (tail_ref[0, c] + r) % depth
            # Neutral path: visible time == device completion time.
            dt_out[c, pos] = done_ref[0, i]
            vt_out[c, pos] = done_ref[0, i]
            rid_out[c, pos] = req_ref[0, i]
            counts_ref[0, c] = r + 1

        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_reap(
    done_time: jax.Array,     # (Q, D) f32 ring
    visible_time: jax.Array,  # (Q, D) f32 ring
    req_id_ring: jax.Array,   # (Q, D) i32 ring
    tail: jax.Array,          # (Q,) i32 free-running producer index
    key: jax.Array,           # (N,) i32 target CQ, == Q for invalid rows
    done: jax.Array,          # (N,) f32 completion times
    req_id: jax.Array,        # (N,) i32
    valid: jax.Array,         # (N,) bool
    *,
    interpret: bool = True,
):
    """One-pass neutral post: returns (done_time', visible_time',
    req_id', counts) with ``counts`` the (Q,) per-CQ valid entries."""
    q, d = done_time.shape
    # Invalid rows carry key == Q; clip for safe counter indexing (the
    # valid gate already keeps them from posting).
    safe_key = jnp.clip(key, 0, q - 1)
    dt, vt, rid, counts = pl.pallas_call(
        functools.partial(_fused_reap_kernel, depth=d),
        grid=(1,),
        in_specs=[
            pl.BlockSpec(done_time.shape, lambda i: (0, 0)),
            pl.BlockSpec(visible_time.shape, lambda i: (0, 0)),
            pl.BlockSpec(req_id_ring.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, q), lambda i: (0, 0)),
            pl.BlockSpec((1, key.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, key.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, key.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, key.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec(done_time.shape, lambda i: (0, 0)),
            pl.BlockSpec(visible_time.shape, lambda i: (0, 0)),
            pl.BlockSpec(req_id_ring.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, q), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, d), jnp.float32),
            jax.ShapeDtypeStruct((q, d), jnp.float32),
            jax.ShapeDtypeStruct((q, d), jnp.int32),
            jax.ShapeDtypeStruct((1, q), jnp.int32),
        ],
        interpret=interpret,
    )(
        done_time, visible_time, req_id_ring, tail[None, :],
        safe_key[None, :], done[None, :], req_id[None, :],
        valid.astype(jnp.int32)[None, :],
    )
    return dt, vt, rid, counts[0]
