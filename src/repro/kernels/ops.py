"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; everywhere else (this CPU
container, unit tests) they execute in ``interpret=True`` mode, which runs
the kernel body in Python against the same BlockSpec pipeline — the
correctness contract tested against ref.py holds in both modes.
"""
from __future__ import annotations

import jax

from repro.kernels import block_gather as _bg
from repro.kernels import decode_attention as _da
from repro.kernels import die_contention as _dc
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_reap as _fr
from repro.kernels import seg_scan as _ss


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def block_gather(flash, idx):
    return _bg.block_gather(flash, idx, interpret=_interpret())


def seg_scan(values, heads, *, chunk: int = 256):
    return _ss.seg_scan(values, heads, chunk=chunk, interpret=_interpret())


def fused_reap(done_time, visible_time, req_id_ring, tail, key, done,
               req_id, valid):
    return _fr.fused_reap(
        done_time, visible_time, req_id_ring, tail, key, done, req_id,
        valid, interpret=_interpret(),
    )


def die_contention(ready, cost, chip, event, chip_busy):
    return _dc.die_contention(
        ready, cost, chip, event, chip_busy, interpret=_interpret()
    )


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)


def decode_attention(q, k_cache, v_cache, lengths, **kw):
    kw.setdefault("interpret", _interpret())
    return _da.decode_attention(q, k_cache, v_cache, lengths, **kw)
