"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -3e38


def block_gather_ref(flash: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather rows: out[i] = flash[idx[i]]."""
    return flash[idx]


def seg_scan_ref(values: jax.Array, heads: jax.Array) -> jax.Array:
    """Segmented inclusive prefix max (restart where heads[i])."""
    def step(carry, x):
        h, v = x
        run = jnp.where(h, v, jnp.maximum(carry, v))
        return run, run

    _, out = jax.lax.scan(step, NEG, (heads, values))
    return out


def attention_ref(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,     # local attention window (tokens back)
    logit_softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference multi-head attention with GQA / local / softcap options."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q * scale, kr).astype(jnp.float32)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    logits = jnp.where(mask[None, None], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vr)


def decode_attention_ref(
    q: jax.Array,            # (B, Hq, D) — one new token per sequence
    k_cache: jax.Array,      # (B, Hkv, S, D)
    v_cache: jax.Array,      # (B, Hkv, S, D)
    lengths: jax.Array,      # (B,) i32 valid cache lengths
    *,
    window: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference single-token decode attention against a KV cache."""
    b, hq, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kr = jnp.repeat(k_cache, group, axis=1)
    vr = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q * scale, kr).astype(jnp.float32)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    pos = jnp.arange(s)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos > lengths[:, None] - 1 - window
    logits = jnp.where(mask[:, None, :], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p.astype(v_cache.dtype), vr)
