"""Segmented prefix-max scan as a Pallas kernel (timing-model hot loop).

The aggregated timing update reduces to a segmented inclusive prefix max
(core/segops.py). This kernel computes it in chunks: each grid step loads a
(1, C) tile, runs a Hillis-Steele doubling scan in-register (static python
loop over log2(C) strides — vector selects/max only), and carries the
running segment value across grid steps through a VMEM scratch cell.
Grid steps execute in order on TPU, so the carry is well-defined.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3e38  # python float: jnp constants would be captured as kernel consts


def _seg_scan_kernel(vals_ref, heads_ref, out_ref, carry_ref, *, chunk: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0, 0] = NEG

    v = vals_ref[0, :]
    f = heads_ref[0, :] != 0

    # Hillis-Steele segmented scan: combine (f,v) pairs at doubling strides.
    stride = 1
    while stride < chunk:
        # Shift right by `stride`; out-of-range positions combine with the
        # identity (f=False, v=NEG).
        idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
        src = jnp.maximum(idx - stride, 0)
        v_prev = jnp.where(idx >= stride, v[src], NEG)
        f_prev = jnp.where(idx >= stride, f[src], False)
        v = jnp.where(f, v, jnp.maximum(v, v_prev))
        f = f | f_prev
        stride *= 2

    # Elements before the chunk's first head continue the carried segment.
    no_head_yet = jnp.cumsum(heads_ref[0, :].astype(jnp.int32)) == 0
    carry = carry_ref[0, 0]
    v = jnp.where(no_head_yet, jnp.maximum(v, carry), v)

    out_ref[0, :] = v
    carry_ref[0, 0] = v[chunk - 1]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def seg_scan(
    values: jax.Array,  # (n,) f32
    heads: jax.Array,   # (n,) bool — segment starts
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    n = values.shape[0]
    pad = (-n) % chunk
    v = jnp.pad(values, (0, pad), constant_values=NEG)
    h = jnp.pad(heads.astype(jnp.int32), (0, pad), constant_values=1)
    m = v.shape[0]
    out = pl.pallas_call(
        functools.partial(_seg_scan_kernel, chunk=chunk),
        grid=(m // chunk,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (0, i)),
            pl.BlockSpec((1, chunk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(v[None, :], h[None, :])
    return out[0, :n]
