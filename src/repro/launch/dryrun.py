import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first backend init. 512 host devices cover both the
# 16x16 single-pod mesh and the 2x16x16 multi-pod mesh.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: build the real step function (train_step / prefill /
serve_step), shard it onto the production mesh with the logical-axis
rules, ``.lower().compile()``, and record memory_analysis +
cost_analysis + roofline terms to a JSON next to EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    import jax

    from repro import configs
    from repro.distributed.sharding import DEFAULT_RULES, use_rules
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import cell_step_and_shardings

    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
    if not configs.runnable(arch, shape):
        rec = {
            "cell": tag, "status": "skipped",
            "reason": "long_500k needs sub-quadratic attention; this arch "
                      "is pure full-attention (see DESIGN.md "
                      "§Arch-applicability)",
        }
        _write(out_dir, tag, rec)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, donate, cfg, sh = cell_step_and_shardings(
        arch, shape, mesh
    )
    try:
        with mesh, use_rules(mesh, DEFAULT_RULES):
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        # Useful-FLOPs reference: 6·N·D (dense) / 6·N_active·D (MoE); for
        # inference shapes, 2·N·D_processed.
        n_active = cfg.active_param_count()
        if sh.kind == "train":
            tokens = sh.global_batch * sh.seq_len
            model_flops = 6.0 * n_active * tokens
        elif sh.kind == "prefill":
            tokens = sh.global_batch * sh.seq_len
            model_flops = 2.0 * n_active * tokens
        else:
            model_flops = 2.0 * n_active * sh.global_batch

        ana = roofline.analyze(compiled, mesh, model_flops)
        mem = compiled.memory_analysis()
        rec = {
            "cell": tag, "status": "ok",
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": str(mem),
            **{k: v for k, v in ana.items()},
        }
    except Exception as e:  # noqa: BLE001 — report failures as data
        rec = {
            "cell": tag, "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    _write(out_dir, tag, rec)
    return rec


def _write(out_dir: str, tag: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro import configs

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    cells = (
        configs.cells() if args.all else [(args.arch, args.shape)]
    )
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (
                    f" bottleneck={rec['bottleneck']}"
                    f" compute={rec['compute_s']:.3e}s"
                    f" mem={rec['memory_s']:.3e}s"
                    f" coll={rec['collective_s']:.3e}s"
                    f" frac={rec['roofline_fraction']:.2f}"
                    f" compile={rec['compile_s']}s"
                )
            elif status == "error":
                extra = " " + rec["error"][:160]
            print(f"[{rec['cell']}] {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
