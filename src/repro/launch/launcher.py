"""Cluster supervision policy: heartbeats, restart, elastic resize,
straggler mitigation. Pure-policy implementation (no real RPC) so the exact
decision logic that would drive a 1000-node deployment is unit-testable.

Deployment model (matching the dry-run meshes): N workers (pods/hosts) emit
heartbeats; the supervisor detects dead workers (heartbeat age > timeout),
requests restart-from-checkpoint, and if spares are exhausted chooses an
elastic downsize to the largest runnable mesh (reshard-on-load handles the
checkpoint). Straggler policy: per-step completion times are tracked; a
worker slower than ``straggler_factor``× the median for ``patience``
consecutive steps gets its data shard re-dispatched to a backup (the
deterministic counter-hashed pipeline makes re-dispatch free).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 1.5
    straggler_patience: int = 3
    allowed_data_sizes: tuple = (16, 8, 4, 2, 1)  # elastic mesh choices


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    slow_streak: int = 0
    alive: bool = True


class Supervisor:
    def __init__(self, n_workers: int, cfg: SupervisorConfig | None = None):
        self.cfg = cfg or SupervisorConfig()
        now = time.time()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(last_heartbeat=now) for i in range(n_workers)
        }
        self.restarts = 0

    # --- heartbeat / liveness -------------------------------------------
    def heartbeat(self, worker: int, t: float | None = None):
        self.workers[worker].last_heartbeat = t or time.time()
        self.workers[worker].alive = True

    def dead_workers(self, now: float | None = None) -> List[int]:
        now = now or time.time()
        return [
            w for w, st in self.workers.items()
            if st.alive and now - st.last_heartbeat > self.cfg.heartbeat_timeout_s
        ]

    def handle_failures(self, now: float | None = None) -> dict:
        """Returns the action: restart in place, or elastic downsize."""
        dead = self.dead_workers(now)
        if not dead:
            return {"action": "none"}
        for w in dead:
            self.workers[w].alive = False
        alive = sum(1 for st in self.workers.values() if st.alive)
        self.restarts += 1
        # Prefer restart at full size (spare capacity assumed = failed nodes
        # come back); if the alive count can't fill the mesh, downsize to
        # the largest allowed data-parallel extent.
        target = next(
            (s for s in self.cfg.allowed_data_sizes if s <= alive),
            None,
        )
        if target is None:
            return {"action": "abort", "dead": dead}
        if target == len(self.workers):
            return {"action": "restart", "dead": dead,
                    "from": "latest_checkpoint"}
        return {
            "action": "elastic_downsize", "dead": dead,
            "new_data_parallel": target, "from": "latest_checkpoint",
            "reshard": True,
        }

    # --- stragglers -------------------------------------------------------
    def report_step_time(self, worker: int, seconds: float):
        st = self.workers[worker]
        st.step_times.append(seconds)
        if len(st.step_times) > 32:
            st.step_times.pop(0)

    def straggler_actions(self) -> List[dict]:
        alive = [w for w, st in self.workers.items() if st.alive]
        lasts = sorted(
            st.step_times[-1] for w, st in self.workers.items()
            if st.alive and st.step_times
        )
        if len(lasts) < max(3, len(alive) // 2):
            return []
        median = lasts[len(lasts) // 2]
        actions = []
        for w in alive:
            st = self.workers[w]
            if not st.step_times:
                continue
            if st.step_times[-1] > self.cfg.straggler_factor * median:
                st.slow_streak += 1
            else:
                st.slow_streak = 0
            if st.slow_streak >= self.cfg.straggler_patience:
                actions.append({
                    "action": "backup_dispatch", "worker": w,
                    "note": "re-dispatch data shard to backup; "
                            "deterministic pipeline regenerates batch",
                })
                st.slow_streak = 0
        return actions
