"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Elastic meshes for downsized restarts and tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
