"""Roofline analysis from compiled dry-run artifacts.

Terms (per device, TPU v5e targets):
    compute    = HLO_FLOPs / 197e12          (bf16 peak per chip)
    memory     = HLO_bytes / 819e9           (HBM bandwidth)
    collective = collective_bytes / 50e9     (per-chip ICI link bw)

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), which silently drops ~L× of the FLOPs in a scan-over-layers
model. We therefore walk the optimized HLO ourselves: per-computation
costs, multiplied through the call graph using each while op's
``known_trip_count`` backend_config. FLOPs come from dot ops (2·M·N·K —
the >95% term in transformer workloads); bytes from operand+output sizes
of non-fused instructions (fusions charged at their call site, matching
what the fused kernel actually moves through HBM); collective bytes from
the operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) of 'bf16[16,128]{1,0}' or a (tuple, of, types)."""
    t = type_str.strip()
    if t.startswith("("):
        inner = t[1 : _match_paren(t, 0)]
        total_e = total_b = 0
        for part in _split_top(inner):
            e, b = _shape_elems_bytes(part)
            total_e += e
            total_b += b
        return total_e, total_b
    m = re.match(r"(\w+)\[([\d,]*)\]", t)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt, 0)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * nb


def _shape_dims(type_str: str) -> List[int]:
    m = re.match(r"\w+\[([\d,]*)\]", type_str.strip())
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _match_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s)


def _split_top(s: str) -> List[str]:
    parts, depth, cur = [], 0, ""
    for ch in s:
        if ch in "({":
            depth += 1
        elif ch in ")}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    return parts


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _parse_instr(line: str) -> Instr | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    # type: either tuple "(...)" or "dtype[...]{...}"
    if rest.startswith("("):
        end = _match_paren(rest, 0) + 1
    else:
        m = re.match(r"\w+\[[\d,]*\](?:\{[^}]*\})?", rest)
        if not m:
            return None
        end = m.end()
    type_str = rest[:end]
    tail = rest[end:].strip()
    m = re.match(r"([\w\-]+)\(", tail)
    if not m:
        return None
    opcode = m.group(1)
    close = _match_paren(tail, tail.find("("))
    argstr = tail[tail.find("(") + 1 : close]
    operands = re.findall(r"%([\w.\-]+)", argstr)
    return Instr(name, type_str, opcode, operands, s)


def parse_hlo(hlo: str):
    """Returns (computations: name->list[Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        h = _HEADER_RE.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            ins = _parse_instr(line)
            if ins:
                comps[cur].append(ins)
    return comps, entry


def _multipliers(comps, entry) -> Tuple[Dict[str, float], set, int]:
    """Execution multiplier per computation + fusion-like set."""
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    fusion_like: set = set()
    unknown = 0
    # call edges: (caller, callee, factor, kind)
    edges: List[Tuple[str, str, float, str]] = []
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "while":
                trip = None
                m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
                if m:
                    trip = int(m.group(1))
                b = re.search(r"body=%?([\w.\-]+)", ins.line)
                c = re.search(r"condition=%?([\w.\-]+)", ins.line)
                t = float(trip) if trip is not None else 1.0
                if trip is None:
                    unknown += 1
                if b:
                    edges.append((cname, b.group(1), t, "while"))
                if c:
                    edges.append((cname, c.group(1), t + 1, "while"))
            else:
                for attr in ("calls", "to_apply", "branch_computations",
                             "true_computation", "false_computation"):
                    for m in re.finditer(
                        rf"{attr}=\{{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}}?",
                        ins.line,
                    ):
                        for callee in re.findall(r"[\w.\-]+", m.group(1)):
                            if callee in comps:
                                edges.append((cname, callee, 1.0, "inline"))
                                fusion_like.add(callee)
    # Propagate through the (DAG) call graph: linear relaxation converges
    # in <= depth passes; each pass recomputes callee sums from the
    # previous pass's caller values.
    for _ in range(128):
        new = defaultdict(float)
        new[entry] = 1.0
        for caller, callee, f, _kind in edges:
            if mult.get(caller, 0.0):
                new[callee] += mult[caller] * f
        if dict(new) == dict(mult):
            break
        mult = new
    return mult, fusion_like, unknown


def _dot_flops(ins: Instr, defs: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.operands:
        return 0.0
    lhs_type = defs.get(ins.operands[0])
    if lhs_type is None:
        return 0.0
    dims = _shape_dims(lhs_type)
    k = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            k *= dims[i]
    return 2.0 * out_elems * k


def walk_costs(hlo: str) -> dict:
    """Trip-count-aware flops / bytes / collective bytes (per device)."""
    comps, entry = parse_hlo(hlo)
    mult, fusion_like, unknown = _multipliers(comps, entry)

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = 0.0
    coll_by_op: Dict[str, float] = defaultdict(float)

    defs_per_comp = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()
    }

    _PLUMBING = {
        "parameter", "convert", "copy", "bitcast", "tuple",
        "get-tuple-element", "constant", "dynamic-slice",
        "dynamic-update-slice", "broadcast", "reshape", "transpose",
    }

    def _plumbing_fusion_bytes(callee: str) -> float | None:
        """Dtype-legalization / layout fusions: the host CPU backend has no
        native bf16 dot, so XLA inserts full-tensor bf16<->f32 convert+copy
        fusions around every cache touch (measured 590 GB/step of phantom
        traffic on decode cells). The TPU target executes bf16 natively and
        updates caches in place, so these fusions are charged only for
        their genuine slice/update traffic. Returns None if the fusion
        does real compute."""
        instrs = comps.get(callee, [])
        if not instrs or any(i.opcode not in _PLUMBING for i in instrs):
            return None
        local_defs = {i.name: i.type_str for i in instrs}
        b = 0.0
        for i in instrs:
            if i.opcode in ("dynamic-slice",):
                b += 2 * _shape_elems_bytes(i.type_str)[1]
            elif i.opcode == "dynamic-update-slice":
                upd = (
                    _shape_elems_bytes(local_defs[i.operands[1]])[1]
                    if len(i.operands) > 1 and i.operands[1] in local_defs
                    else 0
                )
                b += 2 * upd
        return b

    def _param_read_bytes(callee: str, full_bytes: dict) -> dict:
        """Bytes each fusion parameter actually reads: if a parameter is
        consumed only by slicing ops inside the fused computation, charge
        the slice outputs, not the whole tensor."""
        instrs = comps.get(callee, [])
        params = [i for i in instrs if i.opcode == "parameter"]
        by_param: dict = {}
        for p in params:
            consumers = [i for i in instrs if p.name in i.operands]
            if consumers and all(
                c.opcode in ("dynamic-slice", "slice", "gather")
                and c.operands and c.operands[0] == p.name
                for c in consumers
            ):
                by_param[p.name] = sum(
                    _shape_elems_bytes(c.type_str)[1] for c in consumers
                )
            else:
                by_param[p.name] = full_bytes.get(p.name, 0)
        return by_param

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        defs = defs_per_comp[cname]
        for ins in instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, defs)
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                ob = sum(
                    _shape_elems_bytes(defs[o])[1]
                    for o in ins.operands if o in defs
                )
                if ob == 0:
                    ob = _shape_elems_bytes(ins.type_str)[1]
                coll_bytes += m * ob
                coll_by_op[base] += m * ob
            if cname in fusion_like:
                continue  # bytes charged at the fusion call site
            if ins.opcode in _SKIP_BYTES_OPS:
                continue
            out_b = _shape_elems_bytes(ins.type_str)[1]
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                # Reads only the slice it produces (+ tiny indices).
                bytes_accessed += m * 2 * out_b
                continue
            if ins.opcode in ("dynamic-update-slice", "scatter"):
                # In-place update: read+write the update region only.
                upd = (
                    _shape_elems_bytes(defs[ins.operands[1]])[1]
                    if len(ins.operands) > 1 and ins.operands[1] in defs
                    else out_b
                )
                bytes_accessed += m * 2 * upd
                continue
            if ins.opcode == "fusion":
                mcall = re.search(r"calls=%?([\w.\-]+)", ins.line)
                callee = mcall.group(1) if mcall else None
                if callee in comps:
                    pb = _plumbing_fusion_bytes(callee)
                    if pb is not None:
                        bytes_accessed += m * pb
                        continue
                    callee_instrs = comps[callee]
                    pnames = [
                        i.name for i in callee_instrs
                        if i.opcode == "parameter"
                    ]
                    # map call operands -> parameter full sizes by position
                    full = {}
                    for pn, op in zip(pnames, ins.operands):
                        full[pn] = (
                            _shape_elems_bytes(defs[op])[1]
                            if op in defs else 0
                        )
                    reads = _param_read_bytes(callee, full)
                    bytes_accessed += m * (out_b + sum(reads.values()))
                    continue
            in_b = sum(
                _shape_elems_bytes(defs[o])[1]
                for o in ins.operands if o in defs
            )
            bytes_accessed += m * (out_b + in_b)
    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collective_bytes": coll_bytes,
        "collective_by_op": dict(coll_by_op),
        "unknown_trip_loops": unknown,
    }


def analyze(compiled, mesh, model_flops: float | None = None) -> dict:
    """Three roofline terms + bottleneck for one compiled cell."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo = compiled.as_text()
    walked = walk_costs(hlo)

    chips = int(mesh.devices.size)
    flops = walked["flops"]
    bytes_accessed = walked["bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = walked["collective_bytes"] / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    bottleneck = max(terms, key=terms.get)
    mem = compiled.memory_analysis()
    out = {
        "chips": chips,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": walked["collective_bytes"],
        "collective_by_op": walked["collective_by_op"],
        "unknown_trip_loops": walked["unknown_trip_loops"],
        "xla_cost_analysis_flops_once": float(ca.get("flops", 0.0)),
        "xla_cost_analysis_bytes_once": float(ca.get("bytes accessed", 0.0)),
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "hbm_argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "hbm_output_bytes": getattr(mem, "output_size_in_bytes", None),
        "hbm_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "hbm_peak_bytes": (
            (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0)
        ),
    }
    if model_flops:
        out["model_flops_total"] = model_flops
        out["model_flops_per_device"] = model_flops / chips
        out["useful_compute_ratio"] = (
            model_flops / chips / flops if flops else None
        )
    dom = max(terms.values())
    out["roofline_bound_s"] = dom
    out["roofline_fraction"] = compute_s / dom if dom > 0 else None
    return out
