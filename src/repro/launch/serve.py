"""Serving driver: batched prefill + decode with the SSD-backed KV tier.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --smoke \
        [--iops 40e6] [--gen 16]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--iops", type=float, default=2.5e6)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core.types import EngineConfig, SSDConfig
    from repro.models import transformer
    from repro.serving import loop as serve_loop
    from repro.serving.kv_tier import KVTierConfig

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt), 0, cfg.vocab
    )
    ssd = SSDConfig(
        t_max_iops=args.iops,
        n_instances=max(64, int(args.iops // 4e4)), num_blocks=1 << 14,
    )
    scfg = serve_loop.ServeConfig(
        batch=args.batch, prompt_len=args.prompt, gen_tokens=args.gen,
        tier=KVTierConfig(hot_window=16, page_tokens=8),
    )
    out = serve_loop.serve_with_kv_tier(cfg, params, tokens, scfg, ssd)
    print(f"arch={cfg.name} generated {args.gen} tokens x {args.batch} seqs")
    print(f"virtual tokens/s (SSD KV tier @ {args.iops/1e6:.1f} MIOPS): "
          f"{out['tokens_per_s']:.1f}")
    print(f"avg step {out['avg_step_us']:.1f} us "
          f"(storage {out['avg_storage_us']:.1f} us, "
          f"{out['blocks_per_step']} block faults/step, "
          f"demand {out['iops_demand']/1e6:.2f} MIOPS)")
    print(f"wall-clock generation: {out['wall_s']:.2f}s (CPU artifact)")


if __name__ == "__main__":
    main()
