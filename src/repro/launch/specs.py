"""ShapeDtypeStruct input specs + logical-axis trees for every
(arch × shape) dry-run cell — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed.sharding import REPLICATED
from repro.models import transformer
from repro.models.config import ATTN, ATTN_LOCAL, MLSTM, RGLRU, SLSTM, ModelConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Batch specs per shape kind.
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    """(specs, logical axes) for a training batch."""
    spec: dict = {"labels": sds((batch, seq), jnp.int32)}
    axes: dict = {"labels": ("batch", "seq")}
    if cfg.modality == "none":
        spec["tokens"] = sds((batch, seq), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:
        spec["embeds"] = sds((batch, seq, cfg.d_model), cfg.dtype)
        axes["embeds"] = ("batch", "seq", "embed")
    if cfg.rope == "mrope":
        spec["mrope_positions"] = sds((3, batch, seq), jnp.int32)
        axes["mrope_positions"] = (None, "batch", "seq")
    return spec, axes


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    spec: dict = {}
    axes: dict = {}
    if cfg.modality == "none":
        spec["tokens"] = sds((batch, seq), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:
        spec["embeds"] = sds((batch, seq, cfg.d_model), cfg.dtype)
        axes["embeds"] = ("batch", "seq", "embed")
    if cfg.rope == "mrope":
        spec["mrope_positions"] = sds((3, batch, seq), jnp.int32)
        axes["mrope_positions"] = (None, "batch", "seq")
    return spec, axes


def decode_batch_specs(cfg: ModelConfig, batch: int):
    spec: dict = {"pos": sds((), jnp.int32)}
    axes: dict = {"pos": REPLICATED}
    if cfg.modality == "none":
        spec["token"] = sds((batch,), jnp.int32)
        axes["token"] = ("batch",)
    else:
        spec["token"] = sds((batch,), jnp.int32)  # token path unused by stubs
        axes["token"] = ("batch",)
        spec["embeds"] = sds((batch, cfg.d_model), cfg.dtype)
        axes["embeds"] = ("batch", "embed")
    return spec, axes


# ---------------------------------------------------------------------------
# Model params / optimizer / caches: abstract trees + axes.
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: transformer.init_model(jax.random.PRNGKey(0), cfg)
    )


def abstract_opt_state(params):
    f32 = lambda p: sds(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": sds((), jnp.int32),
    }


def opt_axes(p_axes):
    return {
        "m": p_axes,
        "v": p_axes,
        "step": REPLICATED,
    }


def _block_cache_axes(cfg: ModelConfig, kind: str):
    if kind in (ATTN, ATTN_LOCAL):
        kv = ("batch", "kv_heads", "kv_seq", "head_dim")
        return (kv, kv)
    if kind == RGLRU:
        return (("batch", "conv", "lru"), ("batch", "lru"))
    if kind == MLSTM:
        return (
            ("batch", "conv", "heads"),
            (
                ("batch", "heads", "head_dim", "head_dim"),
                ("batch", "heads", "head_dim"),
                ("batch", "heads"),
            ),
        )
    if kind == SLSTM:
        one = ("batch", "heads", "head_dim")
        return (one, one, one, one)
    raise ValueError(kind)


def _prepend(axes, name="layers"):
    from repro.distributed.sharding import _is_axes

    return jax.tree.map(lambda ax: (name, *ax), axes, is_leaf=_is_axes)


def cache_axes(cfg: ModelConfig):
    period = tuple(
        _prepend(_block_cache_axes(cfg, kind)) for kind in cfg.pattern
    )
    rem = tuple(_block_cache_axes(cfg, kind) for kind in cfg.remainder)
    return (period, rem)


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, cache_len)
    )


# ---------------------------------------------------------------------------
# Assembled per-cell specs.
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape: str) -> dict[str, Any]:
    """All abstract inputs + axes for one dry-run cell."""
    cfg = configs.get_config(arch)
    sh = configs.SHAPES[shape]
    params = abstract_params(cfg)
    p_axes = transformer.model_axes(cfg)
    out: dict = {"cfg": cfg, "shape": sh, "params": params,
                 "param_axes": p_axes}
    if sh.kind == "train":
        batch, axes = train_batch_specs(cfg, sh.global_batch, sh.seq_len)
        out["opt_state"] = abstract_opt_state(params)
        out["opt_axes"] = opt_axes(p_axes)
        out["batch"] = batch
        out["batch_axes"] = axes
    elif sh.kind == "prefill":
        batch, axes = prefill_batch_specs(cfg, sh.global_batch, sh.seq_len)
        out["batch"] = batch
        out["batch_axes"] = axes
    else:  # decode
        batch, axes = decode_batch_specs(cfg, sh.global_batch)
        out["batch"] = batch
        out["batch_axes"] = axes
        out["caches"] = abstract_caches(cfg, sh.global_batch, sh.seq_len)
        out["cache_axes"] = cache_axes(cfg)
    return out
