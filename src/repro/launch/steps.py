"""Step builders (train / prefill / decode) + their sharding trees.

These are the functions the dry-run lowers for every (arch × shape × mesh)
cell and the real drivers (train.py / serve.py) execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib


def build_train_step(cfg: ModelConfig, ocfg=None, grad_accum: int = 1
                     ) -> Callable:
    """grad_accum > 1 microbatches over the leading batch dim: activation
    memory scales 1/grad_accum (the §Perf lever that fits the biggest
    train cells in HBM) at the cost of repeating the per-microbatch weight
    all-gathers."""
    ocfg = ocfg or opt_lib.AdamWConfig()

    def loss_of(p, mb):
        return transformer.loss_fn(
            p, cfg,
            mb.get("tokens"), mb["labels"],
            embeds=mb.get("embeds"),
            mrope_positions=mb.get("mrope_positions"),
        )

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss_val, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def split(x, axis=0):
                return x.reshape(
                    (grad_accum, x.shape[axis] // grad_accum)
                    + x.shape[axis + 1:]
                )

            mbs = {
                k: (
                    jnp.moveaxis(
                        v.reshape(v.shape[0], grad_accum, -1, v.shape[-1]),
                        1, 0,
                    )
                    if k == "mrope_positions" else split(v)
                )
                for k, v in batch.items()
            }

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss_val = lsum / grad_accum
        params2, opt2, metrics = opt_lib.apply_updates(
            params, grads, opt_state, ocfg
        )
        metrics["loss"] = loss_val
        return params2, opt2, metrics

    return step


def build_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    def step(params, batch):
        return transformer.prefill(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache_len=cache_len,
            mrope_positions=batch.get("mrope_positions"),
        )

    return step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, batch, caches):
        return transformer.decode_step(
            params, cfg, batch["token"], caches, batch["pos"],
            embeds=batch.get("embeds"),
        )

    return step


def cell_step_and_shardings(arch: str, shape: str, mesh,
                            rules=shd.DEFAULT_RULES, grad_accum: int = 1):
    """Assemble (fn, args_abstract, in_shardings, donate) for a cell."""
    sp = specs_lib.input_specs(arch, shape)
    cfg, sh = sp["cfg"], sp["shape"]
    tree = functools.partial(shd.sharding_tree, rules=rules, mesh=mesh)

    p_shard = shd.sharding_tree(sp["param_axes"], rules, mesh, sp["params"])
    b_shard = shd.sharding_tree(sp["batch_axes"], rules, mesh, sp["batch"])

    if sh.kind == "train":
        fn = build_train_step(cfg, grad_accum=grad_accum)
        o_shard = shd.sharding_tree(
            sp["opt_axes"], rules, mesh, sp["opt_state"]
        )
        args = (sp["params"], sp["opt_state"], sp["batch"])
        in_sh = (p_shard, o_shard, b_shard)
        donate = (0, 1)
    elif sh.kind == "prefill":
        fn = build_prefill_step(cfg, cache_len=sh.seq_len)
        args = (sp["params"], sp["batch"])
        in_sh = (p_shard, b_shard)
        donate = ()
    else:
        fn = build_decode_step(cfg)
        c_shard = shd.sharding_tree(
            sp["cache_axes"], rules, mesh, sp["caches"]
        )
        args = (sp["params"], sp["batch"], sp["caches"])
        in_sh = (p_shard, b_shard, c_shard)
        donate = (2,)
    return fn, args, in_sh, donate, cfg, sh
