"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b \
        [--smoke] [--steps N] [--data D --model M] [--ckpt DIR]

On real hardware the mesh spans the cluster; on this CPU container use
``--smoke`` (reduced config, 1-device mesh) — the same code path, same
sharding rules, same fault-tolerance machinery.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (restart test)")
    args = ap.parse_args()

    import jax

    from repro import configs
    from repro.distributed.sharding import DEFAULT_RULES, use_rules
    from repro.launch.mesh import make_mesh
    from repro.train import loop as train_loop

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    tcfg = train_loop.TrainConfig(
        batch=args.batch, seq=args.seq, steps=args.steps,
        ckpt_every=max(args.steps // 4, 1), ckpt_dir=args.ckpt,
        compress_grads=args.compress_grads,
    )
    mesh = make_mesh(args.data, args.model)
    fail = {args.fail_at} if args.fail_at is not None else None
    with mesh, use_rules(mesh, DEFAULT_RULES):
        res = train_loop.train(
            cfg, tcfg, resume=True, fail_at=fail, log=print
        )
    print(f"done: step={res.step} restarts={res.restarts} "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f} "
          f"({res.wall_s:.1f}s)")


if __name__ == "__main__":
    main()
