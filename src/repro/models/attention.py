"""Attention: GQA projections + flash-style chunked jnp path (dry-run/CPU)
or the Pallas kernels (TPU), with RoPE / M-RoPE, local windows, softcap,
and a KV-cache decode path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, shard_map
from repro.models import layers
from repro.models.config import ATTN_LOCAL, ModelConfig

NEG = -3e38  # python float: jnp module constants leak into jaxprs


def attn_init(key: jax.Array, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * dh, d), dtype) * (hq * dh) ** -0.5,
    }
    a = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.use_bias or cfg.qkv_bias:
        p.update(
            bq=jnp.zeros((hq * dh,), dtype),
            bk=jnp.zeros((hkv * dh,), dtype),
            bv=jnp.zeros((hkv * dh,), dtype),
        )
        a.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    if cfg.use_bias:
        p["bo"] = jnp.zeros((d,), dtype)
        a["bo"] = ("embed",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return p, a


def _project_qkv(params, x, cfg: ModelConfig, positions, mrope_positions):
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.use_bias or cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"])
        k = layers.rms_norm(k, params["k_norm"])
    if cfg.rope == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = layers.apply_mrope(q, mrope_positions, cfg.mrope_sections,
                               cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.mrope_sections,
                               cfg.rope_theta)
    q = constrain(q, ("batch", "heads", "seq", "head_dim"))
    k = constrain(k, ("batch", "kv_heads", "seq", "head_dim"))
    v = constrain(v, ("batch", "kv_heads", "seq", "head_dim"))
    return q, k, v


def _flash_jnp(
    q, k, v, *, causal, window, cap, scale, q_chunk, kv_chunk
):
    """Memory-bounded flash-style attention in pure jnp.

    lax.map over query chunks; inside, lax.scan over kv chunks with an
    online-softmax carry. Peak live memory is O(B·H·q_chunk·kv_chunk),
    independent of S² — which is what lets 32k-token prefill lower within
    HBM in the dry-run.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0
    nq, nk = s // q_chunk, s // kv_chunk
    kg = k.reshape(b, hkv, nk, kv_chunk, d)
    vg = v.reshape(b, hkv, nk, kv_chunk, d)

    def one_q_chunk(iq):
        qc = jax.lax.dynamic_slice_in_dim(q, iq * q_chunk, q_chunk, axis=2)
        qc = qc.reshape(b, hkv, group, q_chunk, d).astype(jnp.float32) * scale
        rows = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ik):
            m, l, acc = carry
            kc = jax.lax.dynamic_index_in_dim(kg, ik, axis=2, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vg, ik, axis=2, keepdims=False)
            sc = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qc, kc.astype(jnp.float32)
            )
            if cap is not None:
                sc = layers.softcap(sc, cap)
            cols = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= cols[None, :] <= rows[:, None]
            if window is not None:
                mask &= cols[None, :] > rows[:, None] - window
            sc = jnp.where(mask, sc, NEG)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            p = jnp.where(mask, jnp.exp(sc - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, q_chunk, 1), NEG)
        l0 = jnp.zeros((b, hkv, group, q_chunk, 1))
        a0 = jnp.zeros((b, hkv, group, q_chunk, d))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk)
        )
        out = acc / jnp.where(l > 0, l, 1.0)
        return out.reshape(b, hq, q_chunk, d)

    if nq == 1:
        out = one_q_chunk(0)
    else:
        out = jax.lax.map(one_q_chunk, jnp.arange(nq))      # (nq, b, hq, qc, d)
        out = jnp.moveaxis(out, 0, 2).reshape(b, hq, s, d)
    return out.astype(q.dtype)


def _flash_core(q, k, v, cfg: ModelConfig, window, scale):
    """Flash attention on *local* tensors (no sharded dims inside)."""
    if cfg.use_pallas:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=True, window=window,
            logit_softcap=cfg.attn_softcap, scale=scale,
        )
    from repro.models.flash_vjp import flash_attention_jnp

    s_len = q.shape[2]
    return flash_attention_jnp(
        q, k, v, True, window, cfg.attn_softcap, scale,
        min(cfg.attn_chunk, s_len), min(cfg.attn_chunk, s_len),
    )


def _sharded_flash(q, k, v, cfg: ModelConfig, window, scale):
    """Tensor-parallel flash attention via explicit shard_map.

    GSPMD cannot partition the chunked flash loops (reshapes + dynamic
    slices over sharded seq/head dims trigger involuntary full
    rematerialization — measured 6.4 GB/device replicated score tensors on
    command-r train_4k). Instead: q heads are sharded over "model", K/V are
    replicated per shard (the GQA KV block is small), each shard expands
    its local q-heads' KV via the global head map and runs the flash core
    on fully local tensors.
    """
    from repro.distributed import sharding as shd

    ctx = shd.current_context()
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    group = hq // hkv
    if ctx is None:
        return _flash_core(q, k, v, cfg, window, scale)
    mesh, rules = ctx
    from jax.sharding import PartitionSpec as P

    dp = shd.spec_for(("batch",), rules, mesh, (q.shape[0],))[0]
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if msize == 1 or hq % msize != 0:
        return _flash_core(q, k, v, cfg, window, scale)
    hq_loc = hq // msize

    def body(q_l, k_l, v_l):
        # q_l: (B_loc, hq_loc, S, D); k_l/v_l: (B_loc, hkv, S, D) replicated.
        base = jax.lax.axis_index("model") * hq_loc
        kv_idx = (base + jnp.arange(hq_loc)) // group
        k_sel = jnp.take(k_l, kv_idx, axis=1)
        v_sel = jnp.take(v_l, kv_idx, axis=1)
        return _flash_core(q_l, k_sel, v_sel, cfg, window, scale)

    qspec = P(dp, "model", None, None)
    kvspec = P(dp, None, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(qspec, kvspec, kvspec),
        out_specs=qspec,
        check_vma=False,
    )(q, k, v)


def _megatron_attention(
    params, x, cfg: ModelConfig, window, scale, positions, mrope_positions,
    mesh, rules,
):
    """Sequence-parallel attention block fully inside shard_map.

    Megatron-SP schedule: all-gather the seq-sharded residual (bf16), run
    column-parallel QKV (local q heads, replicated GQA KV), the local flash
    core, then row-parallel output projection finished with a
    reduce-scatter back onto the seq dim. Doing this explicitly removes
    GSPMD's involuntary full rematerializations (f32 full-seq tensors)
    around the projections — measured 12.9 GB/device on command-r train_4k.
    """
    from repro.distributed import sharding as shd
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    group = hq // hkv
    hq_loc = hq // msize
    dp = shd.spec_for(("batch",), rules, mesh, (b,))[0]

    wspec = {"wq": P(None, "model"), "wk": P(None, None),
             "wv": P(None, None), "wo": P("model", None)}
    for name in ("bq",):
        if name in params:
            wspec["bq"] = P("model")
    for name in ("bk", "bv", "bo", "q_norm", "k_norm"):
        if name in params:
            wspec[name] = P()
    wspec = {k_: v_ for k_, v_ in wspec.items() if k_ in params}
    p_in = {k_: params[k_] for k_ in wspec}

    pos_spec = P(dp, None)
    mpos_spec = P(None, dp, None)

    def body(pp, x_loc, pos, mpos):
        # x_loc: (B_loc, S/msize, D) -> gather full seq in bf16.
        x_full = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        q = x_full @ pp["wq"]                    # (B, S, hq_loc*dh)
        k = x_full @ pp["wk"]
        v = x_full @ pp["wv"]
        if "bq" in pp:
            q = q + pp["bq"]
            k = k + pp["bk"]
            v = v + pp["bv"]
        bl, sl = x_full.shape[:2]
        q = q.reshape(bl, sl, hq_loc, dh).transpose(0, 2, 1, 3)
        k = k.reshape(bl, sl, hkv, dh).transpose(0, 2, 1, 3)
        v = v.reshape(bl, sl, hkv, dh).transpose(0, 2, 1, 3)
        if cfg.qk_norm:
            q = layers.rms_norm(q, pp["q_norm"])
            k = layers.rms_norm(k, pp["k_norm"])
        if cfg.rope == "rope":
            q = layers.apply_rope(q, pos, cfg.rope_theta)
            k = layers.apply_rope(k, pos, cfg.rope_theta)
        elif cfg.rope == "mrope":
            q = layers.apply_mrope(q, mpos, cfg.mrope_sections,
                                   cfg.rope_theta)
            k = layers.apply_mrope(k, mpos, cfg.mrope_sections,
                                   cfg.rope_theta)
        # Local flash: map each local q head to its GQA kv head.
        base = jax.lax.axis_index("model") * hq_loc
        kv_idx = (base + jnp.arange(hq_loc)) // group
        k_sel = jnp.take(k, kv_idx, axis=1)
        v_sel = jnp.take(v, kv_idx, axis=1)
        o = _flash_core(q, k_sel, v_sel, cfg, window, scale)
        o = o.transpose(0, 2, 1, 3).reshape(bl, sl, hq_loc * dh)
        part = o @ pp["wo"]                      # (B, S, D) partial sum
        y = jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                 tiled=True)
        if "bo" in pp:
            y = y + pp["bo"]
        return y

    x_spec = P(dp, "model", None)
    y = shard_map(
        body, mesh=mesh,
        in_specs=(wspec, x_spec, pos_spec, mpos_spec),
        out_specs=x_spec,
        check_vma=False,
    )(p_in, x, positions,
      mrope_positions if mrope_positions is not None
      else jnp.zeros((3, b, s), jnp.int32))
    return y


def attention_apply(
    params: dict,
    x: jax.Array,                 # (B, S, D)
    cfg: ModelConfig,
    kind: str,
    positions: jax.Array,         # (B, S)
    mrope_positions: jax.Array | None = None,
) -> jax.Array:
    """Training / prefill self-attention. Returns (B, S, D)."""
    from repro.distributed import sharding as shd

    window = cfg.window if kind == ATTN_LOCAL else None
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.d_head ** -0.5
    b, s = x.shape[:2]

    ctx = shd.current_context()
    if ctx is not None:
        mesh, rules = ctx
        msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        if (msize > 1 and cfg.n_heads % msize == 0 and s % msize == 0
                and not cfg.use_pallas):
            y = _megatron_attention(
                params, x, cfg, window, scale, positions, mrope_positions,
                mesh, rules,
            )
            return constrain(y, ("batch", "seq", "embed"))

    q, k, v = _project_qkv(params, x, cfg, positions, mrope_positions)
    o = _sharded_flash(q, k, v, cfg, window, scale)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    y = o @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return constrain(y, ("batch", "seq", "embed"))


def attention_prefill(
    params, x, cfg: ModelConfig, kind, positions, mrope_positions=None,
    cache_len: int | None = None,
):
    """Prefill: same as apply but also returns the KV cache (padded to
    ``cache_len``)."""
    window = cfg.window if kind == ATTN_LOCAL else None
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.d_head ** -0.5
    q, k, v = _project_qkv(params, x, cfg, positions, mrope_positions)
    o = _sharded_flash(q, k, v, cfg, window, scale)
    b, s = x.shape[:2]
    out = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    y = out @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    if cache_len is not None and cache_len > s:
        pad = [(0, 0), (0, 0), (0, cache_len - s), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return constrain(y, ("batch", "seq", "embed")), (k, v)


def attention_decode(
    params: dict,
    x: jax.Array,                  # (B, 1, D)
    cache: Tuple[jax.Array, jax.Array],  # k,v: (B, Hkv, S_max, Dh)
    pos: jax.Array,                # () i32 current position
    cfg: ModelConfig,
    kind: str,
    mrope_positions: jax.Array | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode with KV-cache update."""
    window = cfg.window if kind == ATTN_LOCAL else None
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.d_head ** -0.5
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1))
    q, k_new, v_new = _project_qkv(
        params, x, cfg, positions,
        mrope_positions if cfg.rope == "mrope" else None,
    )
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=2)
    s_max = k_cache.shape[2]
    length = pos + 1

    if cfg.use_pallas:
        from repro.kernels import ops as kops

        lengths = jnp.broadcast_to(length, (b,)).astype(jnp.int32)
        o = kops.decode_attention(
            q[:, :, 0], k_cache, v_cache, lengths, window=window,
            logit_softcap=cfg.attn_softcap, scale=scale,
        )[:, :, None, :]
    else:
        hq, hkv = cfg.n_heads, cfg.n_kv_heads
        group = hq // hkv
        # Keep cache operands in their storage dtype and accumulate in f32
        # via preferred_element_type — an explicit .astype(f32) on the
        # cache materializes a full-cache f32 copy (3 GB/device per stack
        # on the 32k decode cells).
        qg = (q.astype(jnp.float32) * scale).astype(q.dtype)
        qg = qg.reshape(b, hkv, group, cfg.d_head)
        if window is not None and window < s_max:
            # Local layers touch only the last `window` entries — slicing
            # the cache cuts per-step read traffic by s_max/window (8x on
            # the gemma2 decode_32k cell).
            start = jnp.clip(length - window, 0, s_max - window)
            k_att = jax.lax.dynamic_slice_in_dim(k_cache, start, window, 2)
            v_att = jax.lax.dynamic_slice_in_dim(v_cache, start, window, 2)
            cols = start + jnp.arange(window)
        else:
            k_att, v_att = k_cache, v_cache
            cols = jnp.arange(s_max)
        logits = jnp.einsum(
            "bhgd,bhkd->bhgk", qg, k_att,
            preferred_element_type=jnp.float32,
        )
        if cfg.attn_softcap is not None:
            logits = layers.softcap(logits, cfg.attn_softcap)
        mask = cols < length
        if window is not None:
            mask &= cols > length - 1 - window
        logits = jnp.where(mask[None, None, None], logits, NEG)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum(
            "bhgk,bhkd->bhgd", p.astype(v_att.dtype), v_att,
            preferred_element_type=jnp.float32,
        )
        o = o.reshape(b, hq, 1, cfg.d_head).astype(x.dtype)

    out = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.d_head)
    y = out @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return constrain(y, ("batch", "seq", "embed")), (k_cache, v_cache)
