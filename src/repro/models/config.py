"""Declarative model configuration covering the full assigned-arch zoo.

One dataclass describes any member of the pool: dense / MoE / SSM / hybrid
LM backbones, with per-layer-pattern heterogeneity (gemma2 local-global
alternation, griffin 1:2 recurrent:attention, xLSTM 7:1 mLSTM:sLSTM)
expressed as a repeating ``pattern`` of block kinds that the runtime scans
over (params stacked per pattern member — HLO stays O(pattern), not O(L)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

# Block kinds appearing in `pattern`.
ATTN = "attn"            # full (global) self-attention + MLP
ATTN_LOCAL = "attn_local"  # sliding-window self-attention + MLP
RGLRU = "rglru"          # griffin RG-LRU recurrent block + MLP
MLSTM = "mlstm"          # xLSTM matrix-memory block (no separate MLP)
SLSTM = "slstm"          # xLSTM scalar-memory block (no separate MLP)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # Block structure.
    pattern: tuple[str, ...] = (ATTN,)
    parallel_block: bool = False        # attn+mlp in parallel (command-r)
    norm: str = "rmsnorm"               # "rmsnorm" | "layernorm"
    post_norms: bool = False            # gemma2 post-sublayer norms
    use_bias: bool = False
    mlp_act: str = "silu"               # "silu" | "gelu"
    mlp_gated: bool = True              # SwiGLU/GeGLU vs plain
    qk_norm: bool = False               # qwen3 per-head q/k RMSNorm
    qkv_bias: bool = False              # qwen2-style bias on q/k/v only

    # Attention details.
    rope: str = "rope"                  # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    window: int = 4096                  # local-attention window
    attn_softcap: float | None = None
    final_softcap: float | None = None
    attn_scale: float | None = None     # override 1/sqrt(d_head)

    # MoE (n_experts == 0 ⇒ dense).
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    d_shared_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Expert-parallel (experts sharded over "model" in shard_map). Measured
    # on qwen3-moe train_4k @16x16: cuts expert-grad all-reduce 12x and
    # total collectives 1.4x, but the seq all-gather/reduce-scatter pair
    # raises the (dominant) memory term 1.5x -> off by default at this
    # scale; the right choice at larger E/d_expert (see EXPERIMENTS §Perf).
    moe_ep: bool = False

    # Recurrent details.
    conv_width: int = 4                 # griffin temporal conv
    rglru_c: float = 8.0

    # Modality frontend stub ("none" | "audio" | "vision").
    modality: str = "none"

    # Embedding / head.
    tie_embeddings: bool = True
    embed_scale_by_dim: bool = False    # gemma: h *= sqrt(d_model)

    # Numerics / execution.
    dtype: str = "bfloat16"             # activation/param compute dtype
    loss_chunk: int = 512               # vocab-proj chunking (memory bound)
    remat: bool = True                  # activation checkpoint per block
    use_pallas: bool = False            # Pallas attention kernels (TPU)
    attn_chunk: int = 1024              # jnp flash-style kv chunk

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> tuple[str, ...]:
        """Layers beyond the scanned periods (unrolled)."""
        r = self.n_layers - self.n_periods * len(self.pattern)
        return self.pattern[:r]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v  # separate LM head
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        out = self.n_heads * self.d_head * d
        mlp_in = 2 * d * self.d_ff if self.mlp_gated else d * self.d_ff
        mlp = mlp_in + self.d_ff * d
        for kind in self.pattern * self.n_periods + self.remainder:
            if kind in (ATTN, ATTN_LOCAL):
                total += qkv + out
                if self.n_experts:
                    e_in = (2 if self.mlp_gated else 1) * d * self.d_expert
                    total += self.n_experts * (e_in + self.d_expert * d)
                    total += d * self.n_experts  # router
                    if self.n_shared_experts:
                        s = self.d_shared_expert
                        total += (2 if self.mlp_gated else 1) * d * s + s * d
                        total += d  # shared gate
                else:
                    total += mlp
            elif kind == RGLRU:
                lru = d  # lru width == d_model
                total += 2 * d * lru + lru * d        # in/gate/out proj
                total += self.conv_width * lru + 2 * lru  # conv + lru params
                total += mlp
            elif kind == MLSTM:
                dh = self.n_heads * self.d_head
                total += d * 2 * dh * 2 + 2 * dh * d  # up-proj x2, q/k/v, down
            elif kind == SLSTM:
                dh = self.n_heads * self.d_head
                total += 4 * d * dh + 4 * dh + d * 4 * self.d_ff // max(self.d_ff, 1)
                total += d * dh
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        e_in = (2 if self.mlp_gated else 1) * self.d_model * self.d_expert
        per_expert = e_in + self.d_expert * self.d_model
        n_attn = sum(
            1 for k in self.pattern * self.n_periods + self.remainder
            if k in (ATTN, ATTN_LOCAL)
        )
        return full - n_attn * (self.n_experts - self.top_k) * per_expert
