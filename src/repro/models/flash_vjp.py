"""Chunked flash attention with a custom VJP (pure jnp).

Without this, jax AD saves every kv-chunk's online-softmax carry for the
backward pass — O(S·nk) f32 residual traffic that dominated the dry-run
roofline (and overflowed HBM). The custom VJP stores only (q, k, v, o, L)
— L = m + log(l) per row — and recomputes attention probabilities
chunk-by-chunk in the backward, the standard flash-attention backward:

    D_i  = Σ_d dO_i · O_i
    P_ij = exp(S_ij − L_i)
    dV_j = Σ_i P_ij dO_i
    dS   = P ⊙ (dO Vᵀ − D)
    dQ_i = Σ_j dS_ij K_j · scale ;  dK_j = Σ_i dS_ij Q_i · scale

Supports GQA grouping, causal masks, local windows, and logit softcap
(dS_raw = dS_cap · (1 − (S_cap/cap)²)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -3e38  # python float: jnp module constants leak into jaxprs


def _mask(rows, cols, causal, window):
    m = jnp.ones((rows.shape[0], cols.shape[0]), bool)
    if causal:
        m &= cols[None, :] <= rows[:, None]
    if window is not None:
        m &= cols[None, :] > rows[:, None] - window
    return m


def _scores(qc, kc, scale, cap):
    """Raw (pre-mask) capped scores. qc: (B,Hkv,G,Cq,D); kc: (B,Hkv,Ck,D)."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qc * scale, kc)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_jnp(
    q, k, v, causal, window, cap, scale, q_chunk, kv_chunk
):
    out, _ = _fwd_impl(q, k, v, causal, window, cap, scale, q_chunk,
                       kv_chunk)
    return out


def _fwd_impl(q, k, v, causal, window, cap, scale, q_chunk, kv_chunk):
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    nq, nk = s // q_chunk, s // kv_chunk
    kf = k.astype(jnp.float32).reshape(b, hkv, nk, kv_chunk, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, nk, kv_chunk, d)

    def one_q(iq):
        qc = jax.lax.dynamic_slice_in_dim(q, iq * q_chunk, q_chunk, 2)
        qc = qc.reshape(b, hkv, g, q_chunk, d).astype(jnp.float32)
        rows = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ik):
            m, l, acc = carry
            kc = kf[:, :, ik]
            vc = vf[:, :, ik]
            sc = _scores(qc, kc, scale, cap)
            cols = ik * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(rows, cols, causal, window)
            sc = jnp.where(msk, sc, NEG)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            p = jnp.where(msk, jnp.exp(sc - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk, 1), NEG)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1))
        a0 = jnp.zeros((b, hkv, g, q_chunk, d))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        o = acc / jnp.where(l > 0, l, 1.0)
        lse = (m[..., 0] + jnp.log(jnp.maximum(l[..., 0], 1e-30)))
        return o.reshape(b, hq, q_chunk, d), lse.reshape(b, hq, q_chunk)

    if nq == 1:
        o, lse = one_q(0)
    else:
        o, lse = jax.lax.map(one_q, jnp.arange(nq))
        o = jnp.moveaxis(o, 0, 2).reshape(b, hq, s, d)
        lse = jnp.moveaxis(lse, 0, 2).reshape(b, hq, s)
    return o.astype(q.dtype), lse


def _fwd_rule(q, k, v, causal, window, cap, scale, q_chunk, kv_chunk):
    o, lse = _fwd_impl(q, k, v, causal, window, cap, scale, q_chunk,
                       kv_chunk)
    return o, (q, k, v, o, lse)


def _bwd_rule(causal, window, cap, scale, q_chunk, kv_chunk, res, do):
    q, k, v, o, lse = res
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    nq, nk = s // q_chunk, s // kv_chunk
    dof = do.astype(jnp.float32)
    dd = jnp.sum(dof * o.astype(jnp.float32), axis=-1)          # (B,Hq,S)
    kf = k.astype(jnp.float32).reshape(b, hkv, nk, kv_chunk, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, nk, kv_chunk, d)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry                   # (B,Hkv,S,D) f32
        sl = lambda x, ax: jax.lax.dynamic_slice_in_dim(
            x, iq * q_chunk, q_chunk, ax
        )
        qc = sl(q, 2).reshape(b, hkv, g, q_chunk, d).astype(jnp.float32)
        doc = sl(dof, 2).reshape(b, hkv, g, q_chunk, d)
        lsec = sl(lse, 2).reshape(b, hkv, g, q_chunk)
        ddc = sl(dd, 2).reshape(b, hkv, g, q_chunk)
        rows = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(inner, ik):
            dk_acc, dv_acc, dq_c = inner
            kc = kf[:, :, ik]
            vc = vf[:, :, ik]
            sc_raw = jnp.einsum("bhgqd,bhkd->bhgqk", qc * scale, kc)
            if cap is not None:
                t = jnp.tanh(sc_raw / cap)
                sc = cap * t
            else:
                sc = sc_raw
            cols = ik * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(rows, cols, causal, window)
            p = jnp.where(msk, jnp.exp(sc - lsec[..., None]), 0.0)
            dv_c = jnp.einsum("bhgqk,bhgqd->bhkd", p, doc)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doc, vc)
            ds = p * (dp - ddc[..., None])
            if cap is not None:
                ds = ds * (1.0 - t * t)
            dq_c = dq_c + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kc) * scale
            dk_c = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qc) * scale
            upd = lambda acc, c: jax.lax.dynamic_update_slice_in_dim(
                acc,
                jax.lax.dynamic_slice_in_dim(acc, ik * kv_chunk, kv_chunk, 2)
                + c,
                ik * kv_chunk, 2,
            )
            return (upd(dk_acc, dk_c), upd(dv_acc, dv_c), dq_c), None

        dq0 = jnp.zeros((b, hkv, g, q_chunk, d))
        (dk_acc, dv_acc, dq_c), _ = jax.lax.scan(
            kv_step, (dk_acc, dv_acc, dq0), jnp.arange(nk)
        )
        return (dk_acc, dv_acc), dq_c.reshape(b, hq, q_chunk, d)

    dk0 = jnp.zeros((b, hkv, s, d))
    dv0 = jnp.zeros((b, hkv, s, d))
    (dk, dv), dq_chunks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq_chunks, 0, 2).reshape(b, hq, s, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_jnp.defvjp(_fwd_rule, _bwd_rule)
