"""Shared layer primitives: norms, RoPE / M-RoPE, MLPs, softcap."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings (+ multimodal M-RoPE).
# ---------------------------------------------------------------------------

def _rope_angles(
    positions: jax.Array, d_head: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` (..., S) -> (..., S, d_head/2)."""
    half = d_head // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array,          # (B, H, S, D)
    positions: jax.Array,  # (B, S)
    theta: float = 10000.0,
) -> jax.Array:
    cos, sin = _rope_angles(positions, x.shape[-1], theta)  # (B, S, D/2)
    cos = cos[:, None]
    sin = sin[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,          # (B, H, S, D)
    positions: jax.Array,  # (3, B, S) — temporal / height / width streams
    sections: Sequence[int],
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head-dim halves are split into
    ``sections`` (in half-dim units), each rotated by its own position
    stream."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # Build per-half-dim position: section j uses positions[j].
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )                                                     # (half,)
    pos = positions.astype(jnp.float32)[sec_id]           # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                        # (B, S, half)
    ang = pos * freq
    cos = jnp.cos(ang)[:, None]
    sin = jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp_apply(params: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    """Gated (SwiGLU/GeGLU) or plain two-layer MLP."""
    if gated:
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        if "b_gate" in params:
            g = g + params["b_gate"]
            u = u + params["b_up"]
        h = _act(act)(g) * u
    else:
        h = x @ params["w_up"]
        if "b_up" in params:
            h = h + params["b_up"]
        h = _act(act)(h)
    y = h @ params["w_down"]
    if "b_down" in params:
        y = y + params["b_down"]
    return y


def mlp_init(
    key: jax.Array, d_model: int, d_ff: int, gated: bool, use_bias: bool,
    dtype,
) -> tuple[dict, dict]:
    """Returns (params, logical axes tree)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }
    a = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if gated:
        p["w_gate"] = jax.random.normal(k1, (d_model, d_ff), dtype) * s_in
        a["w_gate"] = ("embed", "mlp")
    if use_bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        a["b_up"] = ("mlp",)
        p["b_down"] = jnp.zeros((d_model,), dtype)
        a["b_down"] = ("embed",)
        if gated:
            p["b_gate"] = jnp.zeros((d_ff,), dtype)
            a["b_gate"] = ("mlp",)
    return p, a


def norm_init(kind: str, d: int, dtype) -> tuple[dict, dict]:
    if kind == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}, {"w": ("embed",)}
    return (
        {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        {"w": ("embed",), "b": ("embed",)},
    )


def norm_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["w"])
    return layer_norm(x, params["w"], params["b"])
