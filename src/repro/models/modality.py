"""Modality frontend STUBS (per assignment: backbone only).

``[audio]`` (musicgen) and ``[vlm]`` (qwen2-vl) entries specify the
transformer backbone; the EnCodec tokenizer / vision tower are stubs that
provide precomputed frame/patch embeddings with the right shapes, plus the
M-RoPE position-id streams for the VLM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def audio_frame_embeddings(
    key: jax.Array, cfg: ModelConfig, batch: int, seq: int
) -> jax.Array:
    """EnCodec-token embeddings summed over 4 codebooks (upstream stub)."""
    return jax.random.normal(
        key, (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)
    ) * 0.02


def vision_patch_embeddings(
    key: jax.Array, cfg: ModelConfig, batch: int, seq: int,
    image_tokens: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Patch+text embedding stub and (3, B, S) M-RoPE position ids.

    The first ``image_tokens`` positions emulate a dynamic-resolution image
    grid (temporal id frozen, height/width ids raster-scanned); the rest are
    text (all three streams advance together) — matching Qwen2-VL M-RoPE.
    """
    image_tokens = image_tokens if image_tokens is not None else seq // 4
    side = max(int(image_tokens ** 0.5), 1)
    emb = jax.random.normal(
        key, (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)
    ) * 0.02

    idx = jnp.arange(seq)
    is_img = idx < image_tokens
    hh = jnp.where(is_img, idx // side, 0)
    ww = jnp.where(is_img, idx % side, 0)
    # Text positions continue after the image's max position.
    text_pos = jnp.maximum(idx - image_tokens, 0) + side
    t = jnp.where(is_img, 0, text_pos)
    h = jnp.where(is_img, hh, text_pos)
    w = jnp.where(is_img, ww, text_pos)
    pos = jnp.stack([t, h, w]).astype(jnp.int32)           # (3, S)
    pos = jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
    return emb, pos
