"""Mixture-of-Experts: top-k routing, capacity + sort-based LOCAL dispatch
under an explicit ``shard_map``.

Why shard_map: expressing MoE dispatch as global scatter/gather under
GSPMD triggers involuntary full rematerialization (the partitioner cannot
shard data-dependent scatters — we measured 43 GB/device index planes on
the assigned qwen2-moe train_4k cell). Instead each device dispatches its
OWN tokens (batch x seq fully local), with expert weights all-gathered from
their FSDP shards — token compute stays sharded, weight traffic equals the
dense-FSDP all-gather the rest of the model already pays. The roofline's
collective term shows this weight gather; a true all-to-all EP layout is a
further optimization tracked in EXPERIMENTS §Perf.

Dispatch per device is Megablocks-style: sort the (token, expert) pairs by
expert, scatter into an (E, C_local, D) buffer with capacity dropping,
batched per-expert matmuls, weighted scatter-add back. Includes qwen2-moe's
always-on shared experts (sigmoid gate) and the load-balancing aux loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.segops import segment_rank
from repro.distributed import sharding as shd
from repro.models import layers
from repro.models.config import ModelConfig


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d, e, de = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 6)
    s_in, s_out = d ** -0.5, de ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, de), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, de), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, de, d), dtype) * s_out,
    }
    a = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        ds = cfg.d_shared_expert
        sp, sa = layers.mlp_init(ks[4], d, ds, cfg.mlp_gated, False, dtype)
        p["shared"] = sp
        a["shared"] = sa
        p["shared_gate"] = jax.random.normal(ks[5], (d, 1), jnp.float32) * s_in
        a["shared_gate"] = ("embed", None)
    return p, a


def _local_moe(params: dict, xt: jax.Array, cfg: ModelConfig, t_for_cap: int):
    """Per-device dispatch + expert compute. xt: (T_local, D), weights full.

    Returns (out (T_local, D), local aux-loss numerator terms)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum(
        "td,de->te", xt, params["router"].astype(xt.dtype),
        preferred_element_type=jnp.float32,
    )                                                            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Load-balance stats (Switch): fraction routed + mean prob per expert.
    f_e = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)

    cap = int(t_for_cap * k / e * cfg.capacity_factor + 0.999)
    cap = max(4, -(-cap // 4) * 4)
    e_flat = top_e.reshape(t * k)
    p_flat = top_p.reshape(t * k)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    rank = segment_rank(e_flat)
    keep = rank < cap
    slot = jnp.where(keep, e_flat * cap + rank, e * cap)

    buf = jnp.zeros((e * cap, d), xt.dtype).at[slot].set(
        xt[tok_flat], mode="drop"
    ).reshape(e, cap, d)

    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.silu(g) * u if cfg.mlp_act == "silu" else jax.nn.gelu(g) * u
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.silu(h) if cfg.mlp_act == "silu" else jax.nn.gelu(h)
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * cap, d)

    y_rows = y_e[jnp.minimum(slot, e * cap - 1)]
    y_rows = jnp.where(keep[:, None], y_rows, 0.0)
    w = jnp.where(keep, p_flat, 0.0).astype(xt.dtype)
    out = jnp.zeros((t, d), xt.dtype).at[tok_flat].add(y_rows * w[:, None])

    if cfg.n_shared_experts:
        sh = layers.mlp_apply(params["shared"], xt, cfg.mlp_act, cfg.mlp_gated)
        gate = jax.nn.sigmoid(
            xt.astype(jnp.float32) @ params["shared_gate"]
        ).astype(xt.dtype)
        out = out + sh * gate
    return out, f_e, p_e


def _ep_moe(params: dict, x: jax.Array, cfg: ModelConfig, mesh, rules):
    """Expert-parallel MoE: experts sharded over "model" inside shard_map.

    Each model shard: all-gathers the seq-sharded tokens (bf16), routes
    (replicated routing math), dispatches only the (token, expert) pairs
    owned locally, runs its E/msize experts, and contributes its partial
    combine through one reduce-scatter back onto the seq dim. Versus the
    replicated-expert path this cuts BOTH the expert weight gather and the
    expert gradient reduction by the model-axis extent (measured 119
    GB/device/step of expert-grad all-reduce on qwen3-moe train_4k).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in axes if a != "model")
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_loc = e // msize
    x_spec = shd.spec_for(("batch", "seq", None), rules, mesh, x.shape)

    wspec = {
        "router": P(),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    p_in = {k_: params[k_] for k_ in wspec}

    def body(pp, x_loc):
        bl = x_loc.shape[0]
        x_full = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        t = bl * s
        xt = x_full.reshape(t, d)

        # Router in the token dtype with f32 accumulation: an f32 xt copy
        # would make the whole residual cotangent f32 (measured +52% memory
        # term via f32 reduce-scatters).
        logits = jnp.einsum(
            "td,de->te", xt, pp["router"].astype(xt.dtype),
            preferred_element_type=jnp.float32,
        )                                                      # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        f_e = jnp.mean(
            jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0
        )
        p_e = jnp.mean(probs, axis=0)
        f_e = jax.lax.pmean(f_e, dp_axes)
        p_e = jax.lax.pmean(p_e, dp_axes)
        aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)

        my = jax.lax.axis_index("model")
        cap = int(t * k / e * cfg.capacity_factor + 0.999)
        cap = max(4, -(-cap // 4) * 4)
        e_flat = top_e.reshape(t * k)
        p_flat = top_p.reshape(t * k)
        tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        mine = (e_flat // e_loc) == my
        e_local = jnp.where(mine, e_flat % e_loc, e_loc)
        rank = segment_rank(e_local)
        keep = mine & (rank < cap)
        slot = jnp.where(keep, e_local * cap + rank, e_loc * cap)

        buf = jnp.zeros((e_loc * cap, d), xt.dtype).at[slot].set(
            xt[tok_flat], mode="drop"
        ).reshape(e_loc, cap, d)
        if cfg.mlp_gated:
            g = jnp.einsum("ecd,edf->ecf", buf, pp["w_gate"])
            u = jnp.einsum("ecd,edf->ecf", buf, pp["w_up"])
            h = (jax.nn.silu(g) if cfg.mlp_act == "silu"
                 else jax.nn.gelu(g)) * u
        else:
            h = jnp.einsum("ecd,edf->ecf", buf, pp["w_up"])
            h = jax.nn.silu(h) if cfg.mlp_act == "silu" else jax.nn.gelu(h)
        y_e = jnp.einsum("ecf,efd->ecd", h, pp["w_down"]).reshape(
            e_loc * cap, d
        )
        y_rows = y_e[jnp.minimum(slot, e_loc * cap - 1)]
        y_rows = jnp.where(keep[:, None], y_rows, 0.0)
        w = jnp.where(keep, p_flat, 0.0).astype(xt.dtype)
        part = jnp.zeros((t, d), xt.dtype).at[tok_flat].add(
            y_rows * w[:, None]
        )
        # Sum partial expert outputs across shards + scatter back to seq.
        out = jax.lax.psum_scatter(
            part.reshape(bl, s, d), "model", scatter_dimension=1, tiled=True
        )
        return out, aux

    return shd.shard_map(
        body, mesh=mesh,
        in_specs=(wspec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p_in, x)


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balancing loss scalar)."""
    b, s, d = x.shape
    e = cfg.n_experts
    ctx = shd.current_context()
    if ctx is None:
        # Single-device path (smoke tests / CPU examples).
        out, f_e, p_e = _local_moe(params, x.reshape(b * s, d), cfg, b * s)
        aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)
        return out.reshape(b, s, d), aux

    mesh, rules = ctx
    axes = tuple(mesh.axis_names)          # ("pod","data","model") or 2D
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1)
    if (cfg.moe_ep and msize > 1 and e % msize == 0 and s % msize == 0
            and not cfg.n_shared_experts):
        return _ep_moe(params, x, cfg, mesh, rules)
    x_spec = shd.spec_for(("batch", "seq", None), rules, mesh, x.shape)

    # Weight in_specs: replicated E, FSDP-sharded middle dim (the gather
    # back to full D happens inside, over the FSDP axes).
    wspec = {
        "router": P(),
        "w_gate": P(None, None, None),
        "w_up": P(None, None, None),
        "w_down": P(None, None, None),
    }
    if cfg.n_shared_experts:
        wspec["shared"] = jax.tree.map(lambda _: P(), params["shared"])
        wspec["shared_gate"] = P()

    def body(pp, x_loc):
        bl, sl, _ = x_loc.shape
        out, f_e, p_e = _local_moe(pp, x_loc.reshape(bl * sl, d), cfg,
                                   bl * sl)
        # Global stats: mean across every mesh axis (tokens are sharded
        # over batch+seq axes; replicated elsewhere — pmean is exact for
        # equal local token counts).
        f_e = jax.lax.pmean(f_e, axes)
        p_e = jax.lax.pmean(p_e, axes)
        aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)
        return out.reshape(bl, sl, d), aux

    out, aux = shd.shard_map(
        body,
        mesh=mesh,
        in_specs=(wspec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params, x)
    return out, aux
