"""Recurrent sequence-mixing blocks: Griffin RG-LRU, xLSTM mLSTM / sLSTM.

All three expose (init, apply over a sequence, one-step decode) so they plug
into the same block assembly as attention. Parallel-over-time execution:

* RG-LRU — diagonal gated linear recurrence ⇒ exact ``associative_scan``.
* mLSTM  — matrix memory; chunkwise-parallel form (inter-chunk ``lax.scan``
  carrying the stabilized (C, n, m) state, intra-chunk quadratic attention-
  style computation) — the TPU-native adaptation of the paper's kernels.
* sLSTM  — scalar memory with recurrent h-dependence ⇒ inherently
  sequential ``lax.scan`` over time (stabilized exponential gating).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.config import ModelConfig


def checkpointed_scan(f, init, xs, segment: int):
    """lax.scan with gradient checkpointing every ``segment`` steps.

    The naive scan saves its carry at every step for the backward pass —
    ruinous for long sequential recurrences (an sLSTM over 4k tokens saves
    4k copies of (h, c, n, m)). Splitting into rematerialized segments
    stores one carry per segment and recomputes inside.
    """
    n = jax.tree.leaves(xs)[0].shape[0]
    if n <= segment:
        return jax.lax.scan(f, init, xs)
    assert n % segment == 0, (n, segment)
    xs_g = jax.tree.map(
        lambda x: x.reshape(n // segment, segment, *x.shape[1:]), xs
    )

    @jax.checkpoint
    def seg_body(carry, xg):
        return jax.lax.scan(f, carry, xg)

    carry, ys_g = jax.lax.scan(seg_body, init, xs_g)
    ys = jax.tree.map(
        lambda y: y.reshape(n, *y.shape[2:]), ys_g
    )
    return carry, ys


# ---------------------------------------------------------------------------
# Griffin RG-LRU recurrent block.
# ---------------------------------------------------------------------------

def rglru_init(key: jax.Array, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d = cfg.d_model
    lru = d  # lru_width == d_model (recurrentgemma)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    p = {
        "w_x": jax.random.normal(ks[0], (d, lru), dtype) * s,
        "w_gate": jax.random.normal(ks[1], (d, lru), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, lru), dtype) * 0.1,
        "conv_b": jnp.zeros((lru,), dtype),
        "w_input_gate": jax.random.normal(ks[3], (lru, lru), dtype) * s * 0.1,
        "w_rec_gate": jax.random.normal(ks[4], (lru, lru), dtype) * s * 0.1,
        # Λ init so a = exp(-c·softplus(Λ)) spreads over (0.9, 0.999).
        "lambda_": jax.random.uniform(
            ks[5], (lru,), jnp.float32, -4.3, -1.0
        ),
        "w_out": jax.random.normal(ks[6], (lru, d), dtype) * lru ** -0.5,
    }
    a = {
        "w_x": ("embed", "lru"), "w_gate": ("embed", "lru"),
        "conv_w": ("conv", "lru"), "conv_b": ("lru",),
        "w_input_gate": ("lru", "lru"), "w_rec_gate": ("lru", "lru"),
        "lambda_": ("lru",), "w_out": ("lru", "embed"),
    }
    return p, a


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along time. x: (B, S, C), w: (W, C).

    Returns (y, new_state) where state is the trailing (W-1) inputs."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i] for i in range(width)
    ) + b
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return y, new_state


def _rglru_core(
    xc: jax.Array,       # (B, S, lru) conv output
    params: dict,
    cfg: ModelConfig,
    h0: jax.Array | None,
) -> tuple[jax.Array, jax.Array]:
    """RG-LRU recurrence via associative scan. Returns (h, h_last)."""
    xf = xc.astype(jnp.float32)
    gate_in = jax.nn.sigmoid(xf @ params["w_input_gate"].astype(jnp.float32))
    gate_r = jax.nn.sigmoid(xf @ params["w_rec_gate"].astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(params["lambda_"]) * gate_r
    a = jnp.exp(log_a)                                     # (B, S, lru)
    # multiplier sqrt(1 - a^2), computed stably.
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_t = mult * gate_in * xf

    if h0 is not None:
        # Fold the carried state into the first step: b_0 += a_0 * h0.
        b_t = b_t.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    return h.astype(xc.dtype), h[:, -1]


def rglru_apply(
    params: dict, x: jax.Array, cfg: ModelConfig,
    state: tuple | None = None,
) -> tuple[jax.Array, tuple]:
    """Griffin recurrent block over a sequence.

    ``state`` = (conv_state (B, W-1, lru), h (B, lru)) for streaming decode.
    Returns (y (B,S,D), new_state).
    """
    conv_state, h0 = state if state is not None else (None, None)
    gate = jax.nn.gelu(x @ params["w_gate"])
    xr = x @ params["w_x"]
    xc, conv_state = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                  conv_state)
    h, h_last = _rglru_core(xc, params, cfg, h0)
    y = (h * gate) @ params["w_out"]
    y = constrain(y, ("batch", "seq", "embed"))
    return y, (conv_state, h_last)


def rglru_decode(
    params: dict, x: jax.Array, cfg: ModelConfig, state: tuple
) -> tuple[jax.Array, tuple]:
    """One-token step: identical math, S=1 (scan degenerates)."""
    return rglru_apply(params, x, cfg, state)


def rglru_init_state(cfg: ModelConfig, batch: int, dtype):
    lru = cfg.d_model
    return (
        jnp.zeros((batch, cfg.conv_width - 1, lru), dtype),
        jnp.zeros((batch, lru), jnp.float32),
    )


# ---------------------------------------------------------------------------
# xLSTM mLSTM block (matrix memory, chunkwise-parallel).
# ---------------------------------------------------------------------------

def mlstm_init(key: jax.Array, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d = cfg.d_model
    dh = cfg.n_heads * cfg.d_head
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    sh = dh ** -0.5
    p = {
        "w_up": jax.random.normal(ks[0], (d, dh), dtype) * s,     # mlstm path
        "w_z": jax.random.normal(ks[1], (d, dh), dtype) * s,      # output gate
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, dh), dtype) * 0.1,
        "conv_b": jnp.zeros((dh,), dtype),
        "w_q": jax.random.normal(ks[3], (dh, dh), dtype) * sh,
        "w_k": jax.random.normal(ks[4], (dh, dh), dtype) * sh,
        "w_v": jax.random.normal(ks[5], (dh, dh), dtype) * sh,
        "w_if": jax.random.normal(ks[6], (dh, 2 * cfg.n_heads), dtype) * sh,
        "b_if": jnp.zeros((2 * cfg.n_heads,), jnp.float32),
        "w_down": jax.random.normal(ks[7], (dh, d), dtype) * dh ** -0.5,
        "skip_scale": jnp.ones((dh,), dtype),
    }
    a = {
        "w_up": ("embed", "heads"), "w_z": ("embed", "heads"),
        "conv_w": ("conv", "heads"), "conv_b": ("heads",),
        "w_q": ("heads", "heads"), "w_k": ("heads", "heads"),
        "w_v": ("heads", "heads"),
        "w_if": ("heads", None), "b_if": (None,),
        "w_down": ("heads", "embed"), "skip_scale": ("heads",),
    }
    return p, a


def _mlstm_chunk_scan(
    q, k, v,            # (B, H, S, dh)
    logi, logf,         # (B, H, S) f32
    chunk: int,
    carry0=None,
):
    """Stabilized chunkwise-parallel mLSTM. Returns (h, carry)."""
    b, hh, s, dk = q.shape
    dv = v.shape[-1]
    g = min(chunk, s)
    assert s % g == 0
    ng = s // g
    NEG = -3e38

    qs = q.reshape(b, hh, ng, g, dk).astype(jnp.float32) * dk ** -0.5
    ks_ = k.reshape(b, hh, ng, g, dk).astype(jnp.float32)
    vs = v.reshape(b, hh, ng, g, dv).astype(jnp.float32)
    li = logi.reshape(b, hh, ng, g)
    lf = logf.reshape(b, hh, ng, g)

    if carry0 is None:
        c0 = jnp.zeros((b, hh, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, hh, dk), jnp.float32)
        m0 = jnp.full((b, hh), NEG)
        carry0 = (c0, n0, m0)

    idx = jnp.arange(g)
    causal = idx[:, None] >= idx[None, :]                    # (g, g)

    def step(carry, xs):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, lic, lfc = xs                           # (B,H,g,·)
        bcum = jnp.cumsum(lfc, axis=-1)                      # (B,H,g) incl.
        btot = bcum[..., -1]
        # Intra-chunk exponents: D[t,s] = b_t - b_s + i_s (s<=t).
        expo = (
            bcum[..., :, None] - bcum[..., None, :] + lic[..., None, :]
        )
        expo = jnp.where(causal, expo, NEG)
        m_intra = jnp.max(expo, axis=-1)                     # (B,H,g)
        m_inter = m_prev[..., None] + bcum                   # (B,H,g)
        m_t = jnp.maximum(m_inter, m_intra)

        inter_scale = jnp.exp(m_inter - m_t)                 # (B,H,g)
        num_inter = jnp.einsum("bhgd,bhdv->bhgv", qc, c_prev)
        num_inter = num_inter * inter_scale[..., None]
        den_inter = jnp.einsum("bhgd,bhd->bhg", qc, n_prev) * inter_scale

        w_intra = jnp.exp(expo - m_t[..., None])             # (B,H,g,g)
        scores = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * w_intra
        num = num_inter + jnp.einsum("bhts,bhsv->bhtv", scores, vs_ := vc)
        den = den_inter + jnp.sum(scores, axis=-1)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # Carry update (stabilized).
        m_new = jnp.maximum(
            m_prev + btot,
            jnp.max(btot[..., None] - bcum + lic, axis=-1),
        )
        decay = jnp.exp(m_prev + btot - m_new)               # (B,H)
        kw = jnp.exp(btot[..., None] - bcum + lic - m_new[..., None])
        c_new = c_prev * decay[..., None, None] + jnp.einsum(
            "bhsd,bhsv->bhdv", kc * kw[..., None], vc
        )
        n_new = n_prev * decay[..., None] + jnp.sum(
            kc * kw[..., None], axis=2
        )
        return (c_new, n_new, m_new), h

    xs = (
        jnp.moveaxis(qs, 2, 0), jnp.moveaxis(ks_, 2, 0),
        jnp.moveaxis(vs, 2, 0), jnp.moveaxis(li, 2, 0),
        jnp.moveaxis(lf, 2, 0),
    )
    # Checkpoint every 4 chunks: the (C, n, m) matrix-memory carry is the
    # dominant residual; storing it 4x less often trades small recompute
    # for ~4x less backward HBM traffic.
    carry, hs = checkpointed_scan(step, carry0, xs, segment=4)
    h = jnp.moveaxis(hs, 0, 2).reshape(b, hh, s, dv)
    return h, carry


def mlstm_apply(
    params: dict, x: jax.Array, cfg: ModelConfig,
    state: tuple | None = None, chunk: int = 256,
) -> tuple[jax.Array, tuple]:
    """xLSTM mLSTM block. state = (conv_state, (C, n, m))."""
    b, s, d = x.shape
    hh, dh = cfg.n_heads, cfg.d_head
    conv_state, cell = state if state is not None else (None, None)

    xin = x @ params["w_up"]
    z = x @ params["w_z"]
    xc, conv_state = _causal_conv(
        xin, params["conv_w"], params["conv_b"], conv_state
    )
    xc = jax.nn.silu(xc)
    q = (xc @ params["w_q"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    k = (xc @ params["w_k"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    v = (xin @ params["w_v"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    gates = xc.astype(jnp.float32) @ params["w_if"].astype(jnp.float32)
    gates = gates + params["b_if"]
    gates = gates.reshape(b, s, 2, hh).transpose(0, 3, 1, 2)   # (B,H,S,2)
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])

    h, cell = _mlstm_chunk_scan(q, k, v, logi, logf, chunk, cell)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, hh * dh).astype(x.dtype)
    h = h + params["skip_scale"] * xc                     # learnable skip
    y = (h * jax.nn.silu(z)) @ params["w_down"]
    return constrain(y, ("batch", "seq", "embed")), (conv_state, cell)


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype):
    hh, dh = cfg.n_heads, cfg.d_head
    return (
        jnp.zeros((batch, cfg.conv_width - 1, hh * dh), dtype),
        (
            jnp.zeros((batch, hh, dh, dh), jnp.float32),
            jnp.zeros((batch, hh, dh), jnp.float32),
            jnp.full((batch, hh), -3e38),
        ),
    )


# ---------------------------------------------------------------------------
# xLSTM sLSTM block (scalar memory, sequential).
# ---------------------------------------------------------------------------

def slstm_init(key: jax.Array, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d = cfg.d_model
    dh = cfg.n_heads * cfg.d_head
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    sh = dh ** -0.5
    p = {
        # Input projections for z, i, f, o (fused).
        "w_in": jax.random.normal(ks[0], (d, 4 * dh), dtype) * s,
        "b_in": jnp.zeros((4 * dh,), jnp.float32),
        # Recurrent (block-diagonal per head) h -> gates.
        "w_rec": jax.random.normal(
            ks[1], (cfg.n_heads, cfg.d_head, 4 * cfg.d_head), jnp.float32
        ) * cfg.d_head ** -0.5,
        "norm": jnp.zeros((dh,), dtype),
        "w_out": jax.random.normal(ks[2], (dh, d), dtype) * sh,
    }
    a = {
        "w_in": ("embed", "heads"), "b_in": ("heads",),
        "w_rec": (None, "head_dim", "head_dim"),
        "norm": ("heads",), "w_out": ("heads", "embed"),
    }
    return p, a


def slstm_apply(
    params: dict, x: jax.Array, cfg: ModelConfig,
    state: tuple | None = None,
) -> tuple[jax.Array, tuple]:
    """Sequential sLSTM over time (stabilized exponential gating).

    state = (h, c, n, m) each (B, H, dh) / (B, H, dh) / ... per head dims.
    """
    b, s, d = x.shape
    hh, dh = cfg.n_heads, cfg.d_head
    xin = (x @ params["w_in"]).astype(jnp.float32) + params["b_in"]
    xin = xin.reshape(b, s, 4, hh, dh)

    if state is None:
        h0 = jnp.zeros((b, hh, dh), jnp.float32)
        c0 = jnp.zeros((b, hh, dh), jnp.float32)
        n0 = jnp.ones((b, hh, dh), jnp.float32)
        m0 = jnp.zeros((b, hh, dh), jnp.float32)
    else:
        h0, c0, n0, m0 = state

    w_rec = params["w_rec"]  # (H, dh, 4*dh)

    def step(carry, xt):
        h, c, n, m = carry                       # (B, H, dh)
        rec = jnp.einsum("bhd,hdk->bhk", h, w_rec).reshape(b, hh, 4, dh)
        zt = jnp.tanh(xt[:, 0] + rec[:, :, 0])
        it = xt[:, 1] + rec[:, :, 1]
        ft = xt[:, 2] + rec[:, :, 2]
        ot = jax.nn.sigmoid(xt[:, 3] + rec[:, :, 3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    xt_seq = jnp.moveaxis(xin, 1, 0)                           # (S,B,4,H,dh)
    # Strictly sequential over time — checkpoint every 64 steps so the
    # backward stores S/64 carries instead of S.
    (h, c, n, m), hs = checkpointed_scan(
        step, (h0, c0, n0, m0), xt_seq, segment=64
    )
    out = jnp.moveaxis(hs, 0, 1).reshape(b, s, hh * dh)        # (B,S,dh*H)
    out = layers.rms_norm(out.astype(x.dtype), params["norm"])
    y = out @ params["w_out"]
    return constrain(y, ("batch", "seq", "embed")), (h, c, n, m)


def slstm_init_state(cfg: ModelConfig, batch: int, dtype):
    hh, dh = cfg.n_heads, cfg.d_head
    z = jnp.zeros((batch, hh, dh), jnp.float32)
    return (z, z, jnp.ones_like(z), z)
