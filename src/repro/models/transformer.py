"""Block assembly + model forward / loss / prefill / decode.

Layers are stacked *per pattern member* and scanned over periods
(``lax.scan``), so the lowered HLO is O(pattern length), not O(n_layers) —
a 64-layer model lowers one period body plus a loop. Heterogeneous
patterns (gemma2 local/global, griffin rec/rec/attn, xLSTM 7×mLSTM+sLSTM)
keep separate stacked params per member inside each scanned period.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import layers, moe, recurrent
from repro.models.config import (
    ATTN, ATTN_LOCAL, MLSTM, RGLRU, SLSTM, ModelConfig,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Single block.
# ---------------------------------------------------------------------------

def block_init(
    key: jax.Array, cfg: ModelConfig, kind: str
) -> tuple[dict, dict]:
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {}
    a: dict = {}
    p["norm1"], a["norm1"] = layers.norm_init(cfg.norm, d, dt)
    if kind in (ATTN, ATTN_LOCAL):
        p["attn"], a["attn"] = attn.attn_init(ks[0], cfg, dt)
        if not cfg.parallel_block:
            p["norm2"], a["norm2"] = layers.norm_init(cfg.norm, d, dt)
        if cfg.n_experts:
            p["moe"], a["moe"] = moe.moe_init(ks[1], cfg, dt)
        else:
            p["mlp"], a["mlp"] = layers.mlp_init(
                ks[1], d, cfg.d_ff, cfg.mlp_gated, cfg.use_bias, dt
            )
        if cfg.post_norms:
            p["post1"], a["post1"] = layers.norm_init(cfg.norm, d, dt)
            p["post2"], a["post2"] = layers.norm_init(cfg.norm, d, dt)
    elif kind == RGLRU:
        p["rec"], a["rec"] = recurrent.rglru_init(ks[0], cfg, dt)
        p["norm2"], a["norm2"] = layers.norm_init(cfg.norm, d, dt)
        p["mlp"], a["mlp"] = layers.mlp_init(
            ks[1], d, cfg.d_ff, cfg.mlp_gated, cfg.use_bias, dt
        )
    elif kind == MLSTM:
        p["cell"], a["cell"] = recurrent.mlstm_init(ks[0], cfg, dt)
    elif kind == SLSTM:
        p["cell"], a["cell"] = recurrent.slstm_init(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    return p, a


def _mlp_branch(p: dict, x: jax.Array, cfg: ModelConfig):
    if cfg.n_experts:
        return moe.moe_apply(p["moe"], x, cfg)
    return layers.mlp_apply(p["mlp"], x, cfg.mlp_act, cfg.mlp_gated), 0.0


def block_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    positions: jax.Array,
    mrope_positions: jax.Array | None,
) -> tuple[jax.Array, jax.Array]:
    """Training/plain forward. Returns (x', aux)."""
    aux = jnp.float32(0)
    n1 = layers.norm_apply(cfg.norm, p["norm1"], x)
    if kind in (ATTN, ATTN_LOCAL):
        y = attn.attention_apply(p["attn"], n1, cfg, kind, positions,
                                 mrope_positions)
        if cfg.parallel_block:
            m, aux_m = _mlp_branch(p, n1, cfg)
            return x + y + m, aux + aux_m
        if cfg.post_norms:
            y = layers.norm_apply(cfg.norm, p["post1"], y)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, aux_m = _mlp_branch(p, n2, cfg)
        if cfg.post_norms:
            m = layers.norm_apply(cfg.norm, p["post2"], m)
        return x + m, aux + aux_m
    if kind == RGLRU:
        y, _ = recurrent.rglru_apply(p["rec"], n1, cfg)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, _ = _mlp_branch(p, n2, cfg)
        return x + m, aux
    if kind == MLSTM:
        y, _ = recurrent.mlstm_apply(p["cell"], n1, cfg)
        return x + y, aux
    if kind == SLSTM:
        y, _ = recurrent.slstm_apply(p["cell"], n1, cfg)
        return x + y, aux
    raise ValueError(kind)


def block_init_cache(
    cfg: ModelConfig, kind: str, batch: int, cache_len: int
) -> Any:
    dt = _dtype(cfg)
    if kind in (ATTN, ATTN_LOCAL):
        s = cache_len if kind == ATTN else min(cache_len, cfg.window)
        # Local layers could cap the cache at `window`; we keep full length
        # for in-place position indexing simplicity (ring-buffer TODO).
        s = cache_len
        shape = (batch, cfg.n_kv_heads, s, cfg.d_head)
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
    if kind == RGLRU:
        return recurrent.rglru_init_state(cfg, batch, dt)
    if kind == MLSTM:
        return recurrent.mlstm_init_state(cfg, batch, dt)
    if kind == SLSTM:
        return recurrent.slstm_init_state(cfg, batch, dt)
    raise ValueError(kind)


def block_prefill(
    p, x, cfg: ModelConfig, kind, positions, mrope_positions, cache_len
):
    """Forward + produce this block's decode cache."""
    aux = jnp.float32(0)
    n1 = layers.norm_apply(cfg.norm, p["norm1"], x)
    if kind in (ATTN, ATTN_LOCAL):
        y, cache = attn.attention_prefill(
            p["attn"], n1, cfg, kind, positions, mrope_positions, cache_len
        )
        if cfg.parallel_block:
            m, _ = _mlp_branch(p, n1, cfg)
            return x + y + m, cache
        if cfg.post_norms:
            y = layers.norm_apply(cfg.norm, p["post1"], y)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, _ = _mlp_branch(p, n2, cfg)
        if cfg.post_norms:
            m = layers.norm_apply(cfg.norm, p["post2"], m)
        return x + m, cache
    if kind == RGLRU:
        state0 = recurrent.rglru_init_state(cfg, x.shape[0], _dtype(cfg))
        y, state = recurrent.rglru_apply(p["rec"], n1, cfg, state0)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, _ = _mlp_branch(p, n2, cfg)
        return x + m, state
    if kind == MLSTM:
        state0 = recurrent.mlstm_init_state(cfg, x.shape[0], _dtype(cfg))
        y, state = recurrent.mlstm_apply(p["cell"], n1, cfg, state0)
        return x + y, state
    if kind == SLSTM:
        state0 = recurrent.slstm_init_state(cfg, x.shape[0], _dtype(cfg))
        y, state = recurrent.slstm_apply(p["cell"], n1, cfg, state0)
        return x + y, state
    raise ValueError(kind)


def block_decode(
    p, x, cache, pos, cfg: ModelConfig, kind, mrope_positions=None
):
    """One-token decode step. Returns (x', cache')."""
    n1 = layers.norm_apply(cfg.norm, p["norm1"], x)
    if kind in (ATTN, ATTN_LOCAL):
        y, cache = attn.attention_decode(
            p["attn"], n1, cache, pos, cfg, kind, mrope_positions
        )
        if cfg.parallel_block:
            m, _ = _mlp_branch(p, n1, cfg)
            return x + y + m, cache
        if cfg.post_norms:
            y = layers.norm_apply(cfg.norm, p["post1"], y)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, _ = _mlp_branch(p, n2, cfg)
        if cfg.post_norms:
            m = layers.norm_apply(cfg.norm, p["post2"], m)
        return x + m, cache
    if kind == RGLRU:
        y, cache = recurrent.rglru_apply(p["rec"], n1, cfg, cache)
        x = x + y
        n2 = layers.norm_apply(cfg.norm, p["norm2"], x)
        m, _ = _mlp_branch(p, n2, cfg)
        return x + m, cache
    if kind == MLSTM:
        y, cache2 = recurrent.mlstm_apply(p["cell"], n1, cfg, cache, chunk=1)
        return x + y, cache2
    if kind == SLSTM:
        y, cache2 = recurrent.slstm_apply(p["cell"], n1, cfg, cache)
        return x + y, cache2
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole model.
# ---------------------------------------------------------------------------

def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4 + len(cfg.pattern) + len(cfg.remainder))
    p: dict = {
        "embed": jax.random.normal(
            keys[0], (cfg.vocab, cfg.d_model), dt
        ) * cfg.d_model ** -0.5,
    }
    # Stacked per pattern member, scanned over periods.
    stacks = []
    for j, kind in enumerate(cfg.pattern):
        member_keys = jax.random.split(keys[1 + j], cfg.n_periods)
        stacked = jax.vmap(
            lambda k, kind=kind: block_init(k, cfg, kind)[0]
        )(member_keys)
        stacks.append(stacked)
    p["periods"] = tuple(stacks)
    # Remainder layers (unrolled).
    rem = []
    for j, kind in enumerate(cfg.remainder):
        rem.append(block_init(keys[1 + len(cfg.pattern) + j], cfg, kind)[0])
    p["remainder"] = tuple(rem)
    p["final_norm"], _ = layers.norm_init(cfg.norm, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab), dt
        ) * cfg.d_model ** -0.5
    return p


def block_axes(cfg: ModelConfig, kind: str) -> dict:
    """Logical axes for one block, without allocating its params."""
    got: dict = {}

    def f(key):
        p, a = block_init(key, cfg, kind)
        got.update(a)
        return p

    jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return got


def model_axes(cfg: ModelConfig) -> dict:
    """Logical-axis tree mirroring init_model's structure."""
    a: dict = {"embed": ("vocab", "embed")}
    a["periods"] = tuple(
        _prepend_layers(block_axes(cfg, kind)) for kind in cfg.pattern
    )
    a["remainder"] = tuple(block_axes(cfg, kind) for kind in cfg.remainder)
    a["final_norm"] = {"w": ("embed",)} if cfg.norm == "rmsnorm" else {
        "w": ("embed",), "b": ("embed",)
    }
    if not cfg.tie_embeddings:
        a["lm_head"] = ("embed", "vocab")
    return a


def _prepend_layers(axes_tree):
    return jax.tree.map(
        lambda ax: ("layers", *ax),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _embed_tokens(p, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is None:
        h = p["embed"][tokens]
    else:
        h = embeds.astype(_dtype(cfg))
    if cfg.embed_scale_by_dim:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return constrain(h, ("batch", "seq", "embed"))


def forward(
    p: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None = None,      # (B, S) i32
    embeds: jax.Array | None = None,      # (B, S, D) modality stub input
    positions: jax.Array | None = None,   # (B, S)
    mrope_positions: jax.Array | None = None,  # (3, B, S)
) -> tuple[jax.Array, jax.Array]:
    """Backbone forward. Returns (hidden (B,S,D), aux loss)."""
    h = _embed_tokens(p, cfg, tokens, embeds)
    b, s = h.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions, (3, b, s))

    def period_fn(h, pp):
        aux = jnp.float32(0)
        for j, kind in enumerate(cfg.pattern):
            h, a_ = block_apply(pp[j], h, cfg, kind, positions,
                                mrope_positions)
            aux = aux + a_
        return h, aux

    # nothing_saveable: the scan's AD already stores the carry (h) per
    # period; the default checkpoint policy would store a second (f32)
    # copy of it — measured 12.9 GB/device on command-r train_4k.
    body = (
        jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
        if cfg.remat else period_fn
    )
    h, auxs = jax.lax.scan(lambda c, x: body(c, x), h, p["periods"])
    aux = jnp.sum(auxs)
    for j, kind in enumerate(cfg.remainder):
        h, a_ = block_apply(p["remainder"][j], h, cfg, kind, positions,
                            mrope_positions)
        aux = aux + a_
    h = layers.norm_apply(cfg.norm, p["final_norm"], h)
    return h, aux


def _head_matrix(p, cfg: ModelConfig):
    return p["embed"].T if cfg.tie_embeddings else p["lm_head"]


def logits_fn(p, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = _head_matrix(p, cfg)
    logits = (h @ w).astype(jnp.float32)
    return layers.softcap(logits, cfg.final_softcap)


def loss_fn(
    p: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None,
    labels: jax.Array,                    # (B, S) i32
    embeds: jax.Array | None = None,
    mrope_positions: jax.Array | None = None,
) -> jax.Array:
    """Mean next-token cross-entropy, vocab projection chunked over S so the
    (B, S, V) logits tensor never materializes (critical at V=256k)."""
    h, aux = forward(p, cfg, tokens=tokens, embeds=embeds,
                     mrope_positions=mrope_positions)
    b, s, d = h.shape
    w = _head_matrix(p, cfg)
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    nc = s // c
    hc = h.reshape(b, nc, c, d).swapaxes(0, 1)          # (nc, B, c, D)
    yc = labels.reshape(b, nc, c).swapaxes(0, 1)

    # checkpoint: without it the scan saves every chunk's (B,c,V) f32
    # logits for the backward (4.2 GB/device at V=256k) — recompute them.
    @jax.checkpoint
    def chunk_step(tot, xs):
        h_c, y_c = xs
        logits = (h_c @ w).astype(jnp.float32)
        logits = layers.softcap(logits, cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, y_c[..., None], axis=-1
        )[..., 0]
        return tot + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_step, jnp.float32(0), (hc, yc))
    return total / (b * s) + aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode.
# ---------------------------------------------------------------------------

def prefill(
    p: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None = None,
    embeds: jax.Array | None = None,
    cache_len: int | None = None,
    mrope_positions: jax.Array | None = None,
):
    """Run the prompt; returns (last-token logits, caches)."""
    h = _embed_tokens(p, cfg, tokens, embeds)
    b, s = h.shape[:2]
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions, (3, b, s))

    def period_fn(h, pp):
        caches = []
        for j, kind in enumerate(cfg.pattern):
            h, cache = block_prefill(pp[j], h, cfg, kind, positions,
                                     mrope_positions, cache_len)
            caches.append(cache)
        return h, tuple(caches)

    h, caches = jax.lax.scan(lambda c, x: period_fn(c, x), h, p["periods"])
    rem_caches = []
    for j, kind in enumerate(cfg.remainder):
        h, cache = block_prefill(p["remainder"][j], h, cfg, kind, positions,
                                 mrope_positions, cache_len)
        rem_caches.append(cache)
    h = layers.norm_apply(cfg.norm, p["final_norm"], h)
    logits = logits_fn(p, cfg, h[:, -1:])
    return logits[:, 0], (caches, tuple(rem_caches))


def init_caches(cfg: ModelConfig, batch: int, cache_len: int):
    """Zero caches shaped for decode (used by the decode-only dry-run)."""
    period = []
    for kind in cfg.pattern:
        one = block_init_cache(cfg, kind, batch, cache_len)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)), one
        )
        period.append(stacked)
    rem = tuple(
        block_init_cache(cfg, kind, batch, cache_len)
        for kind in cfg.remainder
    )
    return tuple(period), rem


def decode_step(
    p: dict,
    cfg: ModelConfig,
    token: jax.Array,          # (B,) i32  (or (B, D) embeds for stubs)
    caches,
    pos: jax.Array,            # () i32
    embeds: jax.Array | None = None,
):
    """One decode step. Returns (logits (B,V), caches')."""
    if embeds is None:
        h = p["embed"][token][:, None, :]
    else:
        h = embeds[:, None, :].astype(_dtype(cfg))
    if cfg.embed_scale_by_dim:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    b = h.shape[0]
    mrope = (
        jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        if cfg.rope == "mrope" else None
    )
    period_caches, rem_caches = caches

    def period_fn(h, xs):
        pp, pc = xs
        new_c = []
        for j, kind in enumerate(cfg.pattern):
            h, c = block_decode(pp[j], h, pc[j], pos, cfg, kind, mrope)
            new_c.append(c)
        return h, tuple(new_c)

    h, new_period_caches = jax.lax.scan(
        lambda c, x: period_fn(c, x), h, (p["periods"], period_caches)
    )
    new_rem = []
    for j, kind in enumerate(cfg.remainder):
        h, c = block_decode(p["remainder"][j], h, rem_caches[j], pos, cfg,
                            kind, mrope)
        new_rem.append(c)
    h = layers.norm_apply(cfg.norm, p["final_norm"], h)
    logits = logits_fn(p, cfg, h)[:, 0]
    return logits, (new_period_caches, tuple(new_rem))
