"""SSD-backed cold KV-cache tier (CMX/StorageNext-style context tier).

The paper's §I motivation: agentic/long-context serving pushes KV out of
HBM into an IOPS-optimized storage tier accessed by GPU-initiated I/O.
Here the decode path keeps a ``hot_window`` of recent KV pages in HBM; all
older pages live on the emulated SSD and every decode step must fault them
in (full attention reads the whole history). The SwarmIO virtual-time
engine prices those reads, making tokens/s a function of device IOPS —
exactly the study the emulator exists to enable.

Functional path: cold pages are striped over emulated flash blocks; a
step's page reads go through ``StorageClient`` (timing) and the block
gather (data), and the gathered bytes are verified against the live cache
in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.client import ClientState, StorageClient
from repro.core.types import EngineConfig, PlatformModel, SSDConfig
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class KVTierConfig:
    page_tokens: int = 16          # tokens per KV page
    hot_window: int = 1024         # tokens kept in HBM
    block_bytes: int = 512         # SSD I/O granularity
    gpu_step_us: float = 150.0     # modeled per-token GPU compute time


def kv_page_blocks(cfg: ModelConfig, tier: KVTierConfig) -> int:
    """512-byte blocks needed to read one (layer, kv-head) page (K+V)."""
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    page_bytes = 2 * tier.page_tokens * cfg.d_head * dtype_bytes
    return -(-page_bytes // tier.block_bytes)


def cold_blocks_per_step(
    cfg: ModelConfig, tier: KVTierConfig, cache_len: int
) -> int:
    """Block reads a single decode step must fault in (full attention)."""
    cold_tokens = max(cache_len - tier.hot_window, 0)
    pages = -(-cold_tokens // tier.page_tokens)
    return pages * kv_page_blocks(cfg, tier) * cfg.n_kv_heads * cfg.n_layers


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TierState:
    client: ClientState
    clock: jax.Array        # () f32 virtual time (us)


def init_tier(ssd: SSDConfig, ecfg: EngineConfig) -> TierState:
    return TierState(
        client=StorageClient(ssd, ecfg).init_state(),
        clock=jnp.float32(0),
    )


def step_storage_time(
    state: TierState,
    storage: StorageClient,
    flash: jax.Array,
    n_blocks: int,
    batch: int,
    rng_base: jax.Array,
) -> tuple[TierState, jax.Array, jax.Array]:
    """Fault in ``n_blocks`` blocks per sequence (batched) at the current
    virtual time. Returns (state', data, step_storage_latency_us)."""
    total = n_blocks * batch
    lba = (
        (rng_base + jnp.arange(total, dtype=jnp.uint32))
        * jnp.uint32(2654435761)
    ) % jnp.uint32(flash.shape[0])
    client, data, done = storage.read(
        state.client, flash, lba.astype(jnp.int32), state.clock
    )
    t_done = jnp.max(done)
    return (
        TierState(client=client, clock=state.clock),
        data,
        t_done - state.clock,
    )


def decode_tokens_per_s(
    cfg: ModelConfig,
    tier: KVTierConfig,
    ssd: SSDConfig,
    ecfg: EngineConfig,
    batch: int,
    start_len: int,
    n_steps: int,
    plat: PlatformModel | None = None,
    flash_blocks: int = 1 << 14,
    block_words: int = 128,
) -> dict:
    """Virtual-time decode throughput with the SSD-backed cold KV tier.

    Per step: storage faults (priced by the SwarmIO engine) overlap the
    modeled GPU compute; step latency = max(compute, storage). Returns
    aggregate stats incl. achieved IOPS demand vs. device capability.
    """
    storage = StorageClient(ssd, ecfg, plat or PlatformModel())
    flash = (
        jnp.arange(flash_blocks, dtype=jnp.float32)[:, None]
        + jnp.arange(block_words, dtype=jnp.float32)[None, :] * 1e-3
    )
    state = init_tier(ssd, ecfg)

    def one_step(state, step_idx):
        cache_len = start_len + step_idx
        # Static block count for jit: use start_len (cache grows ~n_steps
        # tokens over the run; negligible vs start_len in our studies).
        nb = cold_blocks_per_step(cfg, tier, start_len)
        nb_arr = jnp.int32(nb)
        state2, data, storage_us = step_storage_time(
            state, storage, flash, nb, batch,
            (step_idx * 1315423911 + 7).astype(jnp.uint32),
        )
        step_us = jnp.maximum(storage_us, tier.gpu_step_us)
        return (
            TierState(client=state2.client, clock=state.clock + step_us),
            (storage_us, step_us, data.sum()),
        )

    def body(state, i):
        s2, out = one_step(state, i)
        return s2, out

    state, (storage_us, step_us, _) = jax.lax.scan(
        body, state, jnp.arange(n_steps)
    )
    total_us = float(jnp.sum(step_us))
    nb = cold_blocks_per_step(cfg, tier, start_len)
    return {
        "tokens_per_s": batch * n_steps / (total_us * 1e-6),
        "avg_step_us": total_us / n_steps,
        "avg_storage_us": float(jnp.mean(storage_us)),
        "blocks_per_step": nb * batch,
        "iops_demand": nb * batch / (float(jnp.mean(step_us)) * 1e-6),
    }
