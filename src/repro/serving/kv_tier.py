"""SSD-backed cold KV-cache tier (CMX/StorageNext-style context tier).

The paper's §I motivation: agentic/long-context serving pushes KV out of
HBM into an IOPS-optimized storage tier accessed by GPU-initiated I/O.
Here the decode path keeps a ``hot_window`` of recent KV pages in HBM;
all older pages live on the emulated SSD and every decode step must
fault them in (full attention reads the whole history). The SwarmIO
virtual-time engine prices those reads, making tokens/s a function of
device IOPS — exactly the study the emulator exists to enable.

The tier is backed by the *real* paged KV cache and the *real* device
pipeline end to end:

* logical pages map to SSD LBAs through the live ``PagedKV`` page
  table — physical page p owns the block run ``[p*nb, (p+1)*nb)`` in
  its layer's region of the flash store (``paged_kv.page_run_lbas``);
* a decode step builds ONE mixed ``StorageOps`` batch — cold-page
  fault reads under the latency (decode) tenant, the freshly demoted
  hot-window page's write-back, and an optional background context-
  ingest read stream under the prefill tenant — and submits it
  through the single
  ``StorageClient.submit`` rings -> timing -> flash -> CQ path
  (``submit_striped`` over the array when ``num_devices > 1``);
* the gathered fault bytes are checked against the live pool contents
  every step (``data_check_max_abs`` in the returned stats — the tier
  never fabricates data);
* ``EngineConfig.cache`` puts the stage-0 GPU page cache (and its
  readahead) in front of the faults, so re-faulted cold pages can hit
  at GPU-local latency.

Step latency is ``max(gpu_step_us, storage critical path)`` where the
critical path is the latest completion among the decode tenant's ops;
the background bulk stream is priced (it congests the device and the
fabric) but does not gate the step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.client import ClientState, StorageClient
from repro.core.types import (
    OP_WRITE,
    EngineConfig,
    PlatformModel,
    SSDConfig,
    StorageOps,
)
from repro.models.config import ModelConfig
from repro.serving import paged_kv as pk


@dataclasses.dataclass(frozen=True)
class KVTierConfig:
    page_tokens: int = 16          # tokens per KV page
    hot_window: int = 1024         # tokens kept in HBM
    block_bytes: int = 512         # SSD I/O granularity
    gpu_step_us: float = 150.0     # modeled per-token GPU compute time
    decode_tenant: int = 0         # QoS class: faults + write-backs
    prefill_tenant: int = 1        # QoS class: prefill flush + bulk
    bulk_blocks_per_step: int = 0  # bulk-tenant ingest reads/step
    num_devices: int = 1           # > 1: stripe over a drive array
    stripe_width: int | None = None

    @property
    def hot_pages(self) -> int:
        """Pages of the hot window (>= 1: the page being written)."""
        return max(self.hot_window // self.page_tokens, 1)


def kv_page_blocks(cfg: ModelConfig, tier: KVTierConfig) -> int:
    """512-byte blocks needed to read one (layer, kv-head) page (K+V)."""
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    page_bytes = 2 * tier.page_tokens * cfg.d_head * dtype_bytes
    return -(-page_bytes // tier.block_bytes)


def cold_blocks_per_step(
    cfg: ModelConfig, tier: KVTierConfig, cache_len: int
) -> int:
    """Analytic block reads one decode step faults in (full attention).

    An estimate for sizing studies; the live tier reports the *actual*
    per-step op count from its page tables (``blocks_per_step``).
    """
    cold_tokens = max(cache_len - tier.hot_window, 0)
    pages = -(-cold_tokens // tier.page_tokens)
    return pages * kv_page_blocks(cfg, tier) * cfg.n_kv_heads * cfg.n_layers


def paged_cfg_for(
    cfg: ModelConfig,
    tier: KVTierConfig,
    batch: int,
    start_len: int,
    n_steps: int,
) -> pk.PagedKVConfig:
    """PagedKVConfig sized exactly for a (batch, start_len + n_steps)
    serving run of one layer group of ``cfg``."""
    mp = -(-(start_len + n_steps) // tier.page_tokens)
    return pk.PagedKVConfig(
        page_tokens=tier.page_tokens,
        n_pages=batch * mp,
        max_pages=mp,
        kv_heads=cfg.n_kv_heads,
        head_dim=cfg.d_head,
        dtype=cfg.dtype,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TierState:
    """Live serving-tier state carried across decode steps."""

    client: ClientState      # device/array virtual-time state
    kv: pk.PagedKV           # the real paged KV cache (page tables!)
    flash: jax.Array         # (flash_blocks, block_values) block store
    clock: jax.Array         # () f32 virtual time (us)


def _submit(storage, tier, client, flash, ops, data):
    """One mixed op batch down the unified client path (striped over
    the array when the tier spans multiple drives)."""
    if tier.num_devices > 1:
        return storage.submit_striped(
            client, flash, ops, data=data,
            stripe_width=tier.stripe_width, with_data=True,
        )
    return storage.submit(client, flash, ops, data=data, with_data=True)


def _page_write_ops(kv, pcfg, tier, mask, layers, region, clock, tenant):
    """Write-back ops + payload rows for every masked (B, MP) page,
    tiled over the per-layer LBA regions."""
    nb = pk.page_blocks(pcfg, tier.block_bytes)
    bv = region_block_values(pcfg, tier)
    lay = jnp.arange(layers, dtype=jnp.int32)
    runs = pk.page_run_lbas(kv.page_table, nb)           # (B, MP, nb)
    lba = runs[:, :, None, :] + (lay * region)[None, None, :, None]
    valid = jnp.broadcast_to(mask[:, :, None, None], lba.shape)
    ops = StorageOps.make(
        lba.reshape(-1).astype(jnp.int32), clock,
        opcode=OP_WRITE, tenant=tenant, valid=valid.reshape(-1),
    )
    packed = pk.pack_pages(kv, pcfg, bv)                 # (P, nb, bv)
    rows = packed[jnp.maximum(kv.page_table, 0)]         # (B, MP, nb, bv)
    data = jnp.broadcast_to(
        rows[:, :, None], lba.shape + (bv,)
    ).reshape(-1, bv)
    return ops, data


def region_block_values(pcfg: pk.PagedKVConfig, tier: KVTierConfig) -> int:
    """Values per block row: one flash row is one block's payload."""
    return tier.block_bytes // jnp.dtype(pcfg.dtype).itemsize


def init_tier(
    storage: StorageClient,
    pcfg: pk.PagedKVConfig,
    tier: KVTierConfig,
    batch: int,
    flash_blocks: int,
) -> TierState:
    """Fresh tier: empty paged KV, zeroed block store, clock zero."""
    client = (
        storage.init_array_state(tier.num_devices)
        if tier.num_devices > 1 else storage.init_state()
    )
    bv = region_block_values(pcfg, tier)
    return TierState(
        client=client,
        kv=pk.init_paged(pcfg, batch),
        flash=jnp.zeros((flash_blocks, bv), jnp.float32),
        clock=jnp.float32(0),
    )


def prefill_flush(
    state: TierState,
    storage: StorageClient,
    pcfg: pk.PagedKVConfig,
    tier: KVTierConfig,
    layers: int,
    region: int,
) -> TierState:
    """Flush every cold page of a prefilled cache to its LBA run.

    One bulk-tenant write batch through the same submit path; the clock
    advances to the flush's completion so decode starts with the tier
    durable (every page that decode can fault is on flash).
    """
    cold = pk.cold_page_mask(state.kv, pcfg, tier.hot_pages)
    ops, data = _page_write_ops(
        state.kv, pcfg, tier, cold, layers, region, state.clock,
        tier.prefill_tenant,
    )
    client, flash, _, done = _submit(
        storage, tier, state.client, state.flash, ops, data
    )
    clock = jnp.max(jnp.where(ops.valid, done, state.clock))
    return TierState(
        client=client, kv=state.kv, flash=flash, clock=clock
    )


def tier_step(
    state: TierState,
    storage: StorageClient,
    pcfg: pk.PagedKVConfig,
    tier: KVTierConfig,
    layers: int,
    region: int,
    k_new: jax.Array,        # (B, H, D) this step's keys
    v_new: jax.Array,
    step_idx: jax.Array,     # () i32 — cycles the bulk scratch region
) -> tuple[TierState, dict]:
    """One decode step against the live tier.

    Appends the token to the paged cache, then submits ONE mixed op
    batch: page-table-driven fault reads for every cold page (decode
    tenant), the freshly demoted page's write-back (decode tenant), and
    the optional background bulk-write stream (prefill tenant). Returns
    (state', per-step stats) with the clock advanced by
    ``max(gpu_step_us, storage critical path)``.
    """
    nb = pk.page_blocks(pcfg, tier.block_bytes)
    bv = region_block_values(pcfg, tier)
    b, mp = state.kv.page_table.shape
    lay = jnp.arange(layers, dtype=jnp.int32)

    kv_new = pk.append_token(state.kv, pcfg, k_new, v_new)

    # Fault reads: pages cold *before* this token (the demoted page is
    # still resident this step — it is being evicted, not re-read).
    cold = pk.cold_page_mask(state.kv, pcfg, tier.hot_pages)
    runs = pk.page_run_lbas(state.kv.page_table, nb)      # (B, MP, nb)
    r_lba = runs[:, :, None, :] + (lay * region)[None, None, :, None]
    r_valid = jnp.broadcast_to(cold[:, :, None, None], r_lba.shape)
    n_read = b * mp * layers * nb
    read_ops = StorageOps.make(
        r_lba.reshape(-1).astype(jnp.int32), state.clock,
        tenant=tier.decode_tenant, valid=r_valid.reshape(-1),
    )

    # Write-back: the page (at most one per sequence) that just left
    # the hot window is demoted from HBM to its LBA run.
    demoted = pk.cold_page_mask(kv_new, pcfg, tier.hot_pages) & ~cold
    write_ops, w_data = _page_write_ops(
        kv_new, pcfg, tier, demoted, layers, region, state.clock,
        tier.decode_tenant,
    )

    ops = read_ops.concat(write_ops)
    data = jnp.concatenate([jnp.zeros((n_read, bv)), w_data])

    # Background bulk stream (prefill tenant): context-ingest reads
    # for the *next* requests' prompts, cycling through the scratch
    # region past the KV regions. Priced — it congests the device and
    # the shared fabric against the decode tenant — but never gates
    # the decode step.
    nbulk = tier.bulk_blocks_per_step
    if nbulk:
        scratch0 = layers * region
        scratch = state.flash.shape[0] - scratch0
        b_lba = scratch0 + (
            step_idx * nbulk + jnp.arange(nbulk, dtype=jnp.int32)
        ) % scratch
        bulk_ops = StorageOps.make(
            b_lba.astype(jnp.int32), state.clock,
            tenant=tier.prefill_tenant,
        )
        ops = ops.concat(bulk_ops)
        data = jnp.concatenate([data, jnp.zeros((nbulk, bv))])

    client, flash, out, done = _submit(
        storage, tier, state.client, state.flash, ops, data
    )

    # Step latency: GPU compute overlaps the decode tenant's storage
    # critical path (latest fault or write-back completion).
    gating = ops.valid & (ops.tenant == tier.decode_tenant)
    t_done = jnp.max(jnp.where(gating, done, state.clock))
    storage_us = t_done - state.clock
    step_us = jnp.maximum(storage_us, tier.gpu_step_us)

    # Data integrity: gathered fault bytes == live pool contents. Cold
    # pages' pool rows are immutable (bump allocation, append touches
    # only the hot page), so the block image written at demotion must
    # round-trip bit-exactly.
    packed = pk.pack_pages(kv_new, pcfg, bv)
    exp = packed[jnp.maximum(state.kv.page_table, 0)]     # (B, MP, nb, bv)
    exp = jnp.broadcast_to(exp[:, :, None], r_lba.shape + (bv,))
    err = jnp.abs(out[:n_read].reshape(exp.shape) - exp)
    err = jnp.max(jnp.where(r_valid[..., None], err, 0.0))

    stats = {
        "storage_us": storage_us,
        "step_us": step_us,
        "blocks": jnp.sum(gating),
        "data_err": err,
    }
    state = TierState(
        client=client, kv=kv_new, flash=flash,
        clock=state.clock + step_us,
    )
    return state, stats


def _synth_kv(pcfg: pk.PagedKVConfig, batch: int, t: jax.Array):
    """Deterministic per-token KV payload (distinct across t/b/h/d) so
    the round-trip check actually exercises the bytes."""
    h, d = pcfg.kv_heads, pcfg.head_dim
    tt = (t.astype(jnp.float32) % 509.0) * 0.0625
    grid = (
        jnp.arange(batch, dtype=jnp.float32)[:, None, None] * 0.5
        + jnp.arange(h, dtype=jnp.float32)[None, :, None] * 0.125
        + jnp.arange(d, dtype=jnp.float32)[None, None, :] * 0.03125
    )
    k = (tt + grid).astype(jnp.dtype(pcfg.dtype))
    v = (tt - grid).astype(jnp.dtype(pcfg.dtype))
    return k, v


def decode_tokens_per_s(
    cfg: ModelConfig,
    tier: KVTierConfig,
    ssd: SSDConfig,
    ecfg: EngineConfig,
    batch: int,
    start_len: int,
    n_steps: int,
    plat: PlatformModel | None = None,
    flash_blocks: int = 1 << 14,
) -> dict:
    """Virtual-time decode throughput with the SSD-backed cold KV tier.

    Runs the real tier: prefill ``start_len`` tokens into a paged KV
    cache, flush the cold pages to flash (prefill tenant), then scan
    ``n_steps`` decode steps — each faulting its cold pages through the
    page tables and writing back demotions via one mixed
    ``StorageClient.submit`` batch. Step latency = max(GPU compute,
    storage critical path). Returns aggregate stats incl. achieved
    IOPS demand vs. device capability and the end-to-end
    ``data_check_max_abs`` round-trip error (must be 0.0).
    """
    storage = StorageClient(ssd, ecfg, plat or PlatformModel())
    pcfg = paged_cfg_for(cfg, tier, batch, start_len, n_steps)
    layers = max(cfg.n_layers, 1)
    nb = pk.page_blocks(pcfg, tier.block_bytes)
    region = pcfg.n_pages * nb
    needed = layers * region + max(tier.bulk_blocks_per_step, 1)
    flash_blocks = max(flash_blocks, needed)

    @jax.jit
    def run():
        state = init_tier(storage, pcfg, tier, batch, flash_blocks)

        def fill(kv, t):
            k, v = _synth_kv(pcfg, batch, t)
            return pk.append_token(kv, pcfg, k, v), None

        kv, _ = jax.lax.scan(
            fill, state.kv, jnp.arange(start_len, dtype=jnp.int32)
        )
        state = dataclasses.replace(state, kv=kv)
        state = prefill_flush(state, storage, pcfg, tier, layers, region)

        def body(state, i):
            k, v = _synth_kv(pcfg, batch, start_len + i)
            return tier_step(
                state, storage, pcfg, tier, layers, region, k, v, i
            )

        state, stats = jax.lax.scan(
            body, state, jnp.arange(n_steps, dtype=jnp.int32)
        )
        return stats

    stats = run()
    step_us = stats["step_us"]
    total_us = float(jnp.sum(step_us))
    blocks = float(jnp.mean(stats["blocks"]))
    return {
        "tokens_per_s": batch * n_steps / (total_us * 1e-6),
        "avg_step_us": total_us / n_steps,
        "avg_storage_us": float(jnp.mean(stats["storage_us"])),
        "blocks_per_step": blocks,
        "iops_demand": blocks / (float(jnp.mean(step_us)) * 1e-6),
        "data_check_max_abs": float(jnp.max(stats["data_err"])),
        "hot_pages": tier.hot_pages,
    }
