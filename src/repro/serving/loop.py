"""Serving loop: batched prefill + decode driver over any zoo arch.

Functional generation (the real model, real KV caches) with virtual-time
step accounting from the SSD-backed KV tier — wall-clock generation speed
is a CPU artifact here; the *virtual-time* tokens/s is the deployment
metric the case studies report.

``serve_with_kv_tier`` runs the tier end to end over the real device
pipeline (``kv_tier.decode_tokens_per_s``): a synthetic prefill is
flushed to per-layer flash regions, every decode step faults its cold
pages back in as page-table-driven LBA-run reads through SQ -> timing ->
flash -> CQ, and demoted hot-window pages are written back through the
same path. The returned stats include ``tokens_per_s``, ``avg_step_us``,
``avg_storage_us``, ``blocks_per_step``, ``iops_demand``, and
``data_check_max_abs`` — the latter is the max abs error between the
bytes each fault gathered from flash and the live pool contents, and
must be exactly 0.0.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.types import EngineConfig, SSDConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving import kv_tier


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 4
    prompt_len: int = 32
    gen_tokens: int = 16
    greedy: bool = True
    tier: kv_tier.KVTierConfig = kv_tier.KVTierConfig()


def generate(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,             # (B, prompt)
    scfg: ServeConfig,
) -> dict:
    b, s = tokens.shape
    cache_len = s + scfg.gen_tokens
    logits, caches = jax.jit(
        lambda p, t: transformer.prefill(p, cfg, tokens=t,
                                         cache_len=cache_len)
    )(params, tokens)

    step = jax.jit(
        lambda p, tok, c, pos: transformer.decode_step(p, cfg, tok, c, pos)
    )
    out = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
    t0 = time.time()
    for i in range(scfg.gen_tokens - 1):
        logits, caches = step(params, out[-1], caches, jnp.int32(s + i))
        out.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
    wall = time.time() - t0
    return {
        "tokens": jnp.stack(out, axis=1),
        "wall_s": wall,
    }


def serve_with_kv_tier(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,
    scfg: ServeConfig,
    ssd: SSDConfig,
    ecfg: EngineConfig | None = None,
) -> dict:
    """Generate + virtual-time accounting for the SSD cold-KV tier."""
    gen = generate(cfg, params, tokens, scfg)
    ecfg = ecfg or EngineConfig(num_units=4, fetch_width=64)
    stats = kv_tier.decode_tokens_per_s(
        cfg, scfg.tier, ssd, ecfg,
        batch=tokens.shape[0],
        start_len=tokens.shape[1],
        n_steps=scfg.gen_tokens,
    )
    return {**gen, **stats}
