"""Paged KV cache: vLLM-style page tables over a physical page pool.

Pages are the unit both of HBM allocation and of SSD-tier I/O: a (page
across kv-heads) flattens to a run of 512-byte blocks, so faulting a cold
page from the emulated device is exactly the block-granular read stream
the SwarmIO engine prices, and the data path is the DSA-analogue
``block_gather`` kernel (one copy descriptor per page fragment).

Functional layout:
    pool:        (n_pages, page_tokens, kv_heads, head_dim)  x2 (k, v)
    page_table:  (batch, max_pages) i32 — logical page -> physical page
    lengths:     (batch,) i32
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    page_tokens: int = 16
    n_pages: int = 256          # physical pool size
    max_pages: int = 32         # logical pages per sequence
    kv_heads: int = 4
    head_dim: int = 32
    dtype: str = "bfloat16"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PagedKV:
    k_pool: jax.Array       # (P, T, H, D)
    v_pool: jax.Array
    page_table: jax.Array   # (B, max_pages) i32, -1 = unmapped
    lengths: jax.Array      # (B,) i32
    free_head: jax.Array    # () i32 — bump allocator over the pool


def init_paged(cfg: PagedKVConfig, batch: int) -> PagedKV:
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_pages, cfg.page_tokens, cfg.kv_heads, cfg.head_dim)
    return PagedKV(
        k_pool=jnp.zeros(shape, dt),
        v_pool=jnp.zeros(shape, dt),
        page_table=jnp.full((batch, cfg.max_pages), -1, jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
        free_head=jnp.int32(0),
    )


def append_token(
    kv: PagedKV, cfg: PagedKVConfig,
    k_new: jax.Array,   # (B, H, D)
    v_new: jax.Array,
) -> PagedKV:
    """Append one token per sequence, allocating pages on boundaries."""
    b = k_new.shape[0]
    pos = kv.lengths                                  # (B,)
    lpage = pos // cfg.page_tokens
    offset = pos % cfg.page_tokens
    needs_page = offset == 0
    # Bump-allocate physical pages for sequences crossing a boundary.
    alloc_rank = jnp.cumsum(needs_page.astype(jnp.int32)) - 1
    new_phys = kv.free_head + alloc_rank
    table = kv.page_table.at[jnp.arange(b), lpage].set(
        jnp.where(needs_page, new_phys, kv.page_table[jnp.arange(b), lpage])
    )
    phys = table[jnp.arange(b), lpage]                # (B,)
    k_pool = kv.k_pool.at[phys, offset].set(k_new)
    v_pool = kv.v_pool.at[phys, offset].set(v_new)
    return PagedKV(
        k_pool=k_pool, v_pool=v_pool, page_table=table,
        lengths=kv.lengths + 1,
        free_head=kv.free_head + jnp.sum(needs_page.astype(jnp.int32)),
    )


def gather_dense(
    kv: PagedKV, cfg: PagedKVConfig
) -> Tuple[jax.Array, jax.Array]:
    """Materialize dense (B, H, S_max, D) caches from the page tables
    (the reference path; attention can also consume pages directly)."""
    b = kv.page_table.shape[0]
    phys = jnp.maximum(kv.page_table, 0)              # (B, MP)
    k = kv.k_pool[phys]                               # (B, MP, T, H, D)
    v = kv.v_pool[phys]
    mp, t = cfg.max_pages, cfg.page_tokens
    mask = (kv.page_table >= 0)[:, :, None, None, None]
    k = jnp.where(mask, k, 0).reshape(b, mp * t, cfg.kv_heads, cfg.head_dim)
    v = jnp.where(mask, v, 0).reshape(b, mp * t, cfg.kv_heads, cfg.head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def page_blocks(cfg: PagedKVConfig, block_bytes: int = 512) -> int:
    """512-byte device blocks per page (both K and V fragments)."""
    dt = jnp.dtype(cfg.dtype)
    page_bytes = (
        2 * cfg.page_tokens * cfg.kv_heads * cfg.head_dim * dt.itemsize
    )
    return -(-page_bytes // block_bytes)


def cold_page_mask(
    kv: PagedKV, cfg: PagedKVConfig, hot_pages: int
) -> jax.Array:
    """(B, max_pages) bool — mapped pages older than the hot window.

    Page p of a sequence is *cold* when it trails the page currently
    being written by more than ``hot_pages``: it has been evicted from
    HBM and lives only in its SSD block run.
    """
    cur_page = kv.lengths // cfg.page_tokens
    page_idx = jnp.arange(cfg.max_pages)[None, :]
    return (kv.page_table >= 0) & (page_idx < cur_page[:, None] - hot_pages)


def page_run_lbas(page_table: jax.Array, nb: int) -> jax.Array:
    """(B, MP) page table -> (B, MP, nb) LBA runs.

    Physical page p owns the contiguous block run
    ``[p * nb, (p + 1) * nb)`` — the page-table-driven address map the
    tier reads and writes through (unmapped entries clamp to page 0 and
    must be masked by the caller's valid bits).
    """
    return (
        jnp.maximum(page_table, 0)[..., None] * nb
        + jnp.arange(nb, dtype=jnp.int32)[None, None, :]
    )


def pack_pages(
    kv: PagedKV, cfg: PagedKVConfig, block_values: int
) -> jax.Array:
    """Serialize the pool to its on-device block image.

    Returns (n_pages, nb, block_values) f32: page p's K then V values,
    flattened, zero-padded to ``nb`` blocks of ``block_values`` values
    each (``block_values = block_bytes // dtype_bytes``, so a row *is*
    one device block's payload). Write-back scatters these rows; a
    fault's gathered rows must compare equal — the tier's end-to-end
    data-integrity check.
    """
    p = kv.k_pool.shape[0]
    flat = jnp.concatenate(
        [kv.k_pool.reshape(p, -1), kv.v_pool.reshape(p, -1)], axis=1
    ).astype(jnp.float32)
    nb = -(-flat.shape[1] // block_values)
    pad = nb * block_values - flat.shape[1]
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(p, nb, block_values)


def fault_pages_virtual_time(
    kv: PagedKV, cfg: PagedKVConfig, storage, cstate, flash,
    t_submit, hot_pages: int = 2, tenant: int = 0,
):
    """Price the cold-page faults of one decode step through the SwarmIO
    client: every mapped page older than ``hot_pages`` is a device read of
    ``page_blocks`` blocks at its page-table LBA run. Returns
    (client_state', completion_time)."""
    from repro.core.types import StorageOps

    nb = page_blocks(cfg)
    cold = cold_page_mask(kv, cfg, hot_pages)
    lba = page_run_lbas(kv.page_table, nb).reshape(-1) % flash.shape[0]
    valid = jnp.repeat(cold.reshape(-1), nb)
    ops = StorageOps.make(
        lba.astype(jnp.int32), t_submit, tenant=tenant, valid=valid
    )
    cstate, _, _, done = storage.submit(cstate, flash, ops)
    return cstate, jnp.max(done)
