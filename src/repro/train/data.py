"""Synthetic LM data pipeline with double-buffered host prefetch.

Production shape: an infinite deterministic token stream (counter-hashed,
so any worker can regenerate any batch index — this is what makes restart
and straggler backup-dispatch trivial), prefetched one batch ahead on a
background thread while the device computes (compute/IO overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def synth_batch(
    batch_idx: int, batch: int, seq: int, vocab: int, seed: int = 0
) -> dict:
    """Deterministic batch #batch_idx (regenerable anywhere)."""
    rng = np.random.default_rng(
        np.uint64(seed) + np.uint64(batch_idx) * np.uint64(0x9E3779B9)
    )
    tokens = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Prefetcher:
    """Double-buffered background prefetch of synthetic batches."""

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0,
                 start_idx: int = 0, depth: int = 2):
        self.batch, self.seq, self.vocab, self.seed = batch, seq, vocab, seed
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.idx = start_idx
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        i = self.idx
        while not self._stop.is_set():
            b = synth_batch(i, self.batch, self.seq, self.vocab, self.seed)
            try:
                self.q.put((i, b), timeout=0.5)
                i += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)
