"""Training loop: grad accumulation, compressed cross-replica reduction,
checkpoint/restart, failure injection, and straggler policy.

The loop is deliberately host-driven (one jit'd ``train_step`` per
iteration) so the fault-tolerance machinery — heartbeats, checkpoint
cadence, failure injection, deterministic data re-dispatch — lives in
ordinary Python around a pure step function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.distributed import compression
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch: int = 8
    seq: int = 128
    steps: int = 20
    grad_accum: int = 1
    ckpt_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    compress_grads: bool = False
    seed: int = 0
    opt: opt_lib.AdamWConfig = opt_lib.AdamWConfig()


def make_train_step(
    cfg: ModelConfig, tcfg: TrainConfig
) -> Callable:
    """Build the jit'd (params, opt_state, residuals, batch) -> ... step."""

    def loss_of(params, tokens, labels):
        return transformer.loss_fn(params, cfg, tokens, labels)

    def step(params, opt_state, residuals, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if tcfg.grad_accum > 1:
            b = tokens.shape[0] // tcfg.grad_accum
            tk = tokens.reshape(tcfg.grad_accum, b, -1)
            lb = labels.reshape(tcfg.grad_accum, b, -1)

            def acc_step(carry, xs):
                gsum, lsum = carry
                t, l = xs
                loss, g = jax.value_and_grad(loss_of)(params, t, l)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                acc_step, (g0, jnp.float32(0)), (tk, lb)
            )
            grads = jax.tree.map(
                lambda g: g / tcfg.grad_accum, gsum
            )
            loss = lsum / tcfg.grad_accum
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, tokens, labels)

        if tcfg.compress_grads:
            grads, residuals = compression.compress_tree(grads, residuals)

        params, opt_state, metrics = opt_lib.apply_updates(
            params, grads, opt_state, tcfg.opt
        )
        metrics["loss"] = loss
        return params, opt_state, residuals, metrics

    return jax.jit(step, donate_argnums=(0, 1, 2))


@dataclasses.dataclass
class TrainResult:
    step: int
    losses: list
    restarts: int
    wall_s: float


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    resume: bool = True,
    fail_at: set | None = None,
    log: Callable[[str], None] = lambda s: None,
) -> TrainResult:
    """Run the loop; ``fail_at`` injects a simulated crash at those steps
    (the loop then restarts from the latest checkpoint, proving
    checkpoint/restart end-to-end)."""
    fail_at = set(fail_at or ())
    step_fn = make_train_step(cfg, tcfg)
    losses: list = []
    restarts = 0
    t0 = time.time()

    def cold_start():
        params = transformer.init_model(
            jax.random.PRNGKey(tcfg.seed), cfg
        )
        opt_state = opt_lib.init_opt_state(params)
        residuals = (
            compression.init_residuals(params)
            if tcfg.compress_grads else {}
        )
        return params, opt_state, residuals, 0

    # Resume or cold start.
    start = checkpoint.latest_step(tcfg.ckpt_dir) if resume else None
    if start is not None:
        params, opt_state, residuals, _ = cold_start()
        state, _ = checkpoint.load(
            tcfg.ckpt_dir, {"params": params, "opt": opt_state}, step=start
        )
        params, opt_state = state["params"], state["opt"]
        step0 = start
        log(f"resumed from step {start}")
    else:
        params, opt_state, residuals, step0 = cold_start()

    prefetch = data_lib.Prefetcher(
        tcfg.batch, tcfg.seq, cfg.vocab, tcfg.seed, start_idx=step0
    )
    try:
        it = iter(prefetch)
        step = step0
        while step < tcfg.steps:
            idx, batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if step in fail_at:
                fail_at.discard(step)
                restarts += 1
                log(f"injected failure at step {step}; restarting")
                prefetch.close()
                return_inner = train(
                    cfg, tcfg, resume=True, fail_at=fail_at, log=log
                )
                return TrainResult(
                    return_inner.step,
                    losses + return_inner.losses,
                    restarts + return_inner.restarts,
                    time.time() - t0,
                )
            params, opt_state, residuals, metrics = step_fn(
                params, opt_state, residuals, batch
            )
            losses.append(float(metrics["loss"]))
            step += 1
            if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
                checkpoint.save(
                    tcfg.ckpt_dir, step,
                    {"params": params, "opt": opt_state},
                )
                checkpoint.gc_old(tcfg.ckpt_dir, keep=2)
                log(f"step {step} ckpt saved loss={losses[-1]:.4f}")
    finally:
        prefetch.close()
    return TrainResult(step, losses, restarts, time.time() - t0)
