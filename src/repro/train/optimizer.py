"""AdamW with ZeRO-style sharded state (states inherit the param shardings,
which already carry the FSDP 'fsdp'->data mapping from the rules)."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.int32(0),
    }


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def apply_updates(
    params, grads, state: dict, cfg: AdamWConfig
) -> Tuple[Any, dict, dict]:
    """One AdamW step. Returns (params', state', metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p, new_m, new_v = jax.tree.transpose(
        jax.tree.structure(params), jax.tree.structure((0, 0, 0)), out
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
