"""Workload generators for the SwarmIO-JAX emulation engine."""
from repro.workloads.base import Prefill, Workload, as_workload
from repro.workloads.generators import (
    ClosedLoop,
    MixedReadWrite,
    MultiTenant,
    PoissonOpenLoop,
    SteadyStateMixed,
    TraceReplay,
    ZipfClosedLoop,
)

__all__ = [
    "Prefill",
    "Workload",
    "as_workload",
    "ClosedLoop",
    "MixedReadWrite",
    "MultiTenant",
    "PoissonOpenLoop",
    "SteadyStateMixed",
    "TraceReplay",
    "ZipfClosedLoop",
]
