"""Workload abstraction for the emulation engine.

A ``Workload`` is a *static* (non-pytree) generator object whose methods are
jit-traceable. It owns the three decisions the engine used to hard-code:

  * ``prefill``     — what sits in the SQ rings at t=0
  * ``address`` / ``opcode`` — the request stream's content
  * ``next_submit`` — when (if ever) a completed slot produces the next
                      submission: closed loops key off the completion time,
                      open loops key off the previous *arrival* time (arrival
                      process independent of service), replays never resubmit.

Two engine-side layers interact with these hooks transparently:
completion times fed to ``next_submit`` are the CQ-*reaped* times (the
queue-pair layer, qp.py — identical to device completion under the
neutral QPConfig), and with the stage-0 page cache enabled a proposed
read that hits is completed at GPU-local latency and ``next_submit`` is
re-invoked with that hit completion to chain the slot's next request
(engine.py's bounded hit chase).

Determinism: all randomness is counter-based (xorshift hash of the request
id, the workload seed, and a per-device ``salt``), so workloads are
reproducible, vmap-able across emulated devices, and need no PRNG state
threaded through the engine loop.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.segops import hash_u32, uniform01
from repro.core.types import EngineConfig, SSDConfig, WorkloadConfig

FAR = 3e38  # python float: jnp module constants leak into jaxprs

__all__ = [
    "FAR",
    "Prefill",
    "Workload",
    "as_workload",
    "hash_u32",
    "uniform01",
]


class Prefill(NamedTuple):
    """Entries pre-posted into the SQ rings at t=0; all arrays are (Q, L)."""

    submit: jax.Array   # f32 virtual submission times (row-sorted)
    opcode: jax.Array   # i32
    lba: jax.Array      # i32
    nblocks: jax.Array  # i32
    req_id: jax.Array   # i32
    valid: jax.Array    # bool
    tenant: "jax.Array | None" = None  # i32 QoS class (None = all 0)


@dataclasses.dataclass(frozen=True)
class Workload:
    """Base closed-loop-shaped workload; subclasses override the hooks."""

    io_depth: int = 64            # outstanding requests per SQ
    read_frac: float = 1.0        # fraction of reads
    seed: int = 0
    # Steady-state studies: a generator may declare that the drive it
    # drives should start fully written (``engine.init_state`` then builds
    # the flash array preconditioned, as if ``ssd.preconditioned=True``).
    precondition_drive: bool = False

    # -- counter-based randomness -------------------------------------------
    def _key(self, req_id: jax.Array, salt: jax.Array | int,
             stream: int = 0) -> jax.Array:
        base = (
            req_id.astype(jnp.uint32)
            + jnp.uint32(self.seed) * jnp.uint32(0x9E3779B9)
            + jnp.asarray(salt).astype(jnp.uint32) * jnp.uint32(0x632BE5AB)
            + jnp.uint32(stream) * jnp.uint32(7919)
        )
        return hash_u32(base)

    # -- request-content hooks ----------------------------------------------
    def address(self, req_id: jax.Array, ssd: SSDConfig,
                salt: jax.Array | int = 0) -> jax.Array:
        """Uniform-random LBAs."""
        h = self._key(req_id, salt)
        return (h % jnp.uint32(ssd.num_blocks)).astype(jnp.int32)

    def opcode(self, req_id: jax.Array, salt: jax.Array | int = 0,
               tenant: jax.Array | None = None) -> jax.Array:
        """Read/write decision. ``tenant`` (the request's QoS class, as
        assigned by ``tenant_of_sq``) is threaded in by the engine so
        multi-tenant generators can mix per class; the single-class
        base ignores it."""
        del tenant
        h = self._key(req_id, salt, stream=1)
        return (
            (h % jnp.uint32(1000)).astype(jnp.float32)
            >= self.read_frac * 1000
        ).astype(jnp.int32)

    def tenant_of_sq(self, sq_id: jax.Array, cfg: EngineConfig,
                     salt: jax.Array | int = 0) -> jax.Array:
        """QoS/tenant class served by each SQ (single class by default).

        Multi-tenant generators override this to partition the SQs
        across classes; the assignment must be static per SQ so a
        closed-loop slot never migrates between tenants mid-run. Keep
        every service *unit* single-class (a unit's fetched batch
        enters the timing lock together, so a unit internally mixing
        classes chains a latency tenant to its bulk neighbor's slowest
        wire frame under any lock order). Whether the single-class
        units themselves must be contiguous depends on the lock:
        under ``lock_order="program"`` misaligned (interleaved) unit
        placements still serialize in loop order — a latency unit
        queues behind the bulk unit one position earlier even when its
        batch arrived first — while ``"ready_time"`` admits units by
        batch arrival and isolates interleaved placements too (see
        ``MultiTenant(interleave=True)`` and fig29).
        """
        del cfg, salt
        return jnp.zeros_like(sq_id)

    # -- lifecycle hooks -----------------------------------------------------
    def prefill(self, cfg: EngineConfig, ssd: SSDConfig,
                salt: jax.Array | int = 0) -> Prefill:
        """``io_depth`` entries per SQ at t~0 (staggered for a total order)."""
        q, d = cfg.num_sqs, self.io_depth
        if d > cfg.sq_depth:
            raise ValueError(
                f"io_depth={d} exceeds sq_depth={cfg.sq_depth}"
            )
        req_id = (
            jnp.arange(q, dtype=jnp.int32)[:, None] * d
            + jnp.arange(d, dtype=jnp.int32)[None, :]
        )
        submit = (
            jnp.arange(d, dtype=jnp.float32)[None, :] * 1e-3
            + jnp.arange(q, dtype=jnp.float32)[:, None] * 1e-5
        )
        tenant = jnp.broadcast_to(
            self.tenant_of_sq(
                jnp.arange(q, dtype=jnp.int32), cfg, salt
            )[:, None],
            (q, d),
        )
        return Prefill(
            submit=submit,
            opcode=self.opcode(req_id, salt, tenant=tenant),
            lba=self.address(req_id, ssd, salt),
            nblocks=jnp.ones((q, d), jnp.int32),
            req_id=req_id,
            valid=jnp.ones((q, d), bool),
            tenant=tenant,
        )

    def sharded(self, num_shards: int) -> "Workload":
        """Adapt this generator to an M-drive array (one instance per
        drive, distinguished by the per-device ``salt``).

        Salt-aware generators (closed loop, Poisson, Zipf) already
        produce M independent request streams from the salt alone and
        return ``self``; fixed-trace replays override this to stripe
        the trace's rows across the drives (``engine.init_array_state``
        calls it with the array size).
        """
        del num_shards
        return self

    def next_submit(
        self,
        new_req: jax.Array,      # (N,) i32 ids of the would-be new requests
        done: jax.Array,         # (N,) f32 completion time of the old request
        valid: jax.Array,        # (N,) bool old request was real
        anchor: jax.Array,       # (N,) f32 last submit time posted to the
                                 #     row's SQ (open-loop arrival chaining)
        cfg: EngineConfig,
        ssd: SSDConfig,
        salt: jax.Array | int = 0,
    ) -> Tuple[jax.Array, jax.Array]:
        """When the slot's next submission occurs. Returns (time, valid).

        Rows are SQ-major: ``N == num_sqs * fetch_width``, row ``i`` belongs
        to SQ ``i // fetch_width``. Returned times must be non-decreasing
        within each SQ's valid rows OR derived from ``done`` (the engine
        sorts each SQ's batch, but cross-round order must be respected by
        chaining open-loop arrivals off ``anchor``).
        """
        raise NotImplementedError


def as_workload(wl: "Workload | WorkloadConfig") -> "Workload":
    """Adapt a legacy ``WorkloadConfig`` to the closed-loop generator."""
    if isinstance(wl, Workload):
        return wl
    from repro.workloads.generators import ClosedLoop

    return ClosedLoop(
        io_depth=wl.io_depth, read_frac=wl.read_frac, seed=wl.seed,
        resubmit_delay_us=wl.resubmit_delay_us,
    )
