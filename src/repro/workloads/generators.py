"""The workload generators.

``ClosedLoop``    fio/BaM analogue: each slot resubmits after completion plus
                  think time (the engine's original behavior, refactored in).
``PoissonOpenLoop``  open-loop arrivals at a configured aggregate rate: each
                  SQ is an independent Poisson process whose arrival times
                  chain off the engine-tracked per-SQ anchor, independent of
                  completions (latency grows without bound past saturation —
                  the signature open-loop behavior the closed loop can't
                  show).
``ZipfClosedLoop``  closed loop with power-law (Zipf-like) LBA skew: a
                  ``theta``-parameterized hot spot concentrating accesses on
                  low addresses, for channel-imbalance studies paired with
                  ``routing="lba_hash"``.
``MixedReadWrite``  closed loop with a read/write mix (default 70/30) and
                  optional Zipf skew — the flash backend's bread-and-butter
                  load: programs serialize per chip and sustained writes
                  drain the free-page pool toward the GC watermark.
``SteadyStateMixed``  the same mix on a *preconditioned* drive: the
                  generator asks the engine to start the flash array fully
                  written, so GC price is paid from the first write batch
                  (the steady-state regime fresh-drive runs overstate).
``MultiTenant``   closed loop with the SQs partitioned across tenant (QoS)
                  classes, each with its own read/write mix — the request
                  stream the fabric's weighted-fair arbiter
                  (``FabricConfig.qos_weights``) arbitrates between.
``TraceReplay``   fixed-trace replay: a (time, lba, opcode) list is dealt
                  round-robin across SQs at t=0 and never resubmits.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EngineConfig
from repro.workloads.base import FAR, Prefill, Workload, uniform01


@dataclasses.dataclass(frozen=True)
class ClosedLoop(Workload):
    """Closed-loop synthetic workload (fio / BaM analogue)."""

    resubmit_delay_us: float = 1.0  # client think time after completion

    def next_submit(self, new_req, done, valid, anchor, cfg, ssd,
                    salt=0) -> Tuple[jax.Array, jax.Array]:
        return done + jnp.float32(self.resubmit_delay_us), valid


@dataclasses.dataclass(frozen=True)
class MixedReadWrite(ClosedLoop):
    """Closed loop mixing reads and writes, optionally Zipf-skewed.

    ``read_frac`` (inherited) sets the read/write split per request —
    0.7 models the canonical 70/30 mix. Addresses follow
    P(lba <= x) = (x/N)^(1-theta): theta=0 is uniform, theta→1
    concentrates nearly all mass on the lowest addresses (the standard
    continuous hot-spot approximation of a Zipf popularity distribution
    over blocks, inverse-CDF sampled so it stays hash-based). One
    generator covers the mixed-skewed loads the flash backend's GC and
    chip-contention studies need.
    """

    read_frac: float = 0.7
    theta: float = 0.0

    def address(self, req_id, ssd, salt=0):
        if not 0.0 <= self.theta < 1.0:
            raise ValueError(f"theta={self.theta} must be in [0, 1)")
        u = uniform01(self._key(req_id, salt))
        alpha = 1.0 / (1.0 - self.theta)
        x = jnp.power(u, jnp.float32(alpha)) * ssd.num_blocks
        return jnp.clip(x.astype(jnp.int32), 0, ssd.num_blocks - 1)


@dataclasses.dataclass(frozen=True)
class ZipfClosedLoop(MixedReadWrite):
    """Read-only closed loop with power-law address skew (Zipf hot spot)."""

    read_frac: float = 1.0
    theta: float = 0.9


@dataclasses.dataclass(frozen=True)
class SteadyStateMixed(MixedReadWrite):
    """Mixed read/write load on a steady-state (fully written) drive.

    Declares ``precondition_drive`` so ``engine.init_state`` starts the
    flash array with every logical page live: only the over-provisioned
    spare pool separates the first write burst from the GC watermark,
    which is where production drives actually operate.
    """

    precondition_drive: bool = True


@dataclasses.dataclass(frozen=True)
class MultiTenant(ClosedLoop):
    """Closed loop with the SQs partitioned across tenant (QoS) classes.

    By default the SQ range splits into T *contiguous* blocks — SQ q
    serves tenant ``q * T // num_sqs`` — so each class owns whole
    service units (static, a slot never migrates mid-run). With
    ``interleave=True`` the assignment is round-robin — SQ q serves
    tenant ``q % T`` — the *misaligned* placement real multi-tenant
    deployments end up with when queues are grabbed first-come: tenant
    units alternate through the unit loop, so under the program-order
    timing lock every latency-tenant unit queues behind the bulk unit
    one loop position earlier even when its batch arrived first. This
    is the regime ``lock_order="ready_time"`` exists for (fig29); keep
    ``num_units == num_sqs`` so each unit stays single-tenant — the
    lock serializes whole units, so a unit *internally* mixing classes
    cannot be isolated by any acquisition order. Each class draws its
    own read/write mix from ``tenant_read_frac`` — e.g. ``(1.0, 0.0)``
    is the fig26 pairing of a latency-sensitive read tenant with a
    bulk-write tenant whose large TX payloads would starve the reads'
    SQEs on a shared link without QoS. Pair with
    ``FabricConfig.qos_weights`` (same length, same order) to give the
    fabric's weighted-fair arbiter the classes to arbitrate; per-tenant
    achieved throughput lands in ``Metrics.tenant_completed``/
    ``tenant_share()`` and tail latency in ``tenant_p99_us()``.
    """

    tenant_read_frac: tuple = (1.0, 0.0)
    interleave: bool = False

    def __post_init__(self) -> None:
        if len(self.tenant_read_frac) < 1:
            raise ValueError("tenant_read_frac must name >= 1 tenant")
        if any(not 0.0 <= rf <= 1.0 for rf in self.tenant_read_frac):
            raise ValueError(
                f"tenant_read_frac={self.tenant_read_frac} entries "
                "must be in [0, 1]"
            )

    @property
    def num_tenants(self) -> int:
        return len(self.tenant_read_frac)

    def tenant_of_sq(self, sq_id, cfg, salt=0):
        del salt
        t = self.num_tenants
        if cfg.num_sqs < t:
            raise ValueError(
                f"num_sqs={cfg.num_sqs} cannot host {t} tenant classes"
            )
        if self.interleave:
            return sq_id % jnp.int32(t)
        return sq_id * jnp.int32(t) // jnp.int32(cfg.num_sqs)

    def opcode(self, req_id, salt=0, tenant=None):
        if tenant is None:
            return super().opcode(req_id, salt)
        rf = jnp.asarray(self.tenant_read_frac, jnp.float32)[
            jnp.clip(tenant, 0, self.num_tenants - 1)
        ]
        h = self._key(req_id, salt, stream=1)
        return (
            (h % jnp.uint32(1000)).astype(jnp.float32) >= rf * 1000
        ).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PoissonOpenLoop(Workload):
    """Open-loop Poisson arrivals at ``rate_iops`` aggregate requests/s.

    Each SQ carries an independent Poisson process of rate
    ``rate_iops / num_sqs``: every posted arrival is the previous arrival in
    that SQ plus an exponential gap, chained off the engine-tracked per-SQ
    ``anchor`` — so arrival times never react to completions (open loop) and
    stay time-sorted within each in-order ring. A completed ring slot merely
    *materializes* the SQ's next pending arrival, which bounds in-flight
    work at ``num_sqs * io_depth`` slots; past device saturation arrivals
    outpace service and queueing latency grows without bound — the
    signature open-loop behavior the closed loop can't show.
    """

    rate_iops: float = 1e6

    def mean_gap_us(self, cfg: EngineConfig) -> float:
        """Mean inter-arrival time within one SQ, in virtual us."""
        return cfg.num_sqs / self.rate_iops * 1e6

    def gap_us(self, req_id: jax.Array, cfg: EngineConfig,
               salt: jax.Array | int = 0) -> jax.Array:
        """Exponential inter-arrival sample for this request id."""
        u = uniform01(self._key(req_id, salt, stream=2))
        return -jnp.log(u) * jnp.float32(self.mean_gap_us(cfg))

    def prefill(self, cfg, ssd, salt=0) -> Prefill:
        base = super().prefill(cfg, ssd, salt)
        # Chained per-SQ arrivals from t=0: cumulative exponential gaps.
        submit = jnp.cumsum(self.gap_us(base.req_id, cfg, salt), axis=1)
        return base._replace(submit=submit)

    def next_submit(self, new_req, done, valid, anchor, cfg, ssd,
                    salt=0) -> Tuple[jax.Array, jax.Array]:
        # Rows are SQ-major (num_sqs, fetch_width): each SQ's m completed
        # slots materialize its next m arrivals, chained off the anchor.
        gaps = jnp.where(valid, self.gap_us(new_req, cfg, salt), 0.0)
        chained = jnp.cumsum(
            gaps.reshape(cfg.num_sqs, -1), axis=1
        ).reshape(new_req.shape)
        return anchor + chained, valid


@dataclasses.dataclass(frozen=True)
class TraceReplay(Workload):
    """Replay a fixed (time, lba, opcode) trace; no resubmission.

    The trace is time-sorted and dealt round-robin across SQs (entry i goes
    to SQ ``i % num_sqs``), which preserves per-SQ time order. Build with
    ``TraceReplay.from_trace``; the whole trace must fit in the rings.

    On an M-drive array (``num_shards = M``, set by
    ``engine.init_array_state`` via ``sharded``) the trace is striped
    across the drives: drive d replays exactly the rows whose time-sorted
    trace index i satisfies ``i % M == d``, arrival times preserved — so
    aggregate array numbers measure the one trace split M ways, not M
    identical copies of it.
    """

    submit: tuple = ()   # static nested tuples, one row per SQ — hashable
    lba: tuple = ()
    ops: tuple = ()
    mask: tuple = ()
    num_shards: int = 1  # M-drive striping (1 = whole trace on one drive)

    @staticmethod
    def from_trace(
        times_us, lbas, opcodes, cfg: EngineConfig
    ) -> "TraceReplay":
        times_us = np.asarray(times_us, np.float32)
        lbas = np.asarray(lbas, np.int32)
        opcodes = np.asarray(opcodes, np.int32)
        if not (times_us.shape == lbas.shape == opcodes.shape):
            raise ValueError("trace arrays must have identical shapes")
        t = len(times_us)
        q = cfg.num_sqs
        length = max(-(-t // q), 1)
        if length > cfg.sq_depth:
            raise ValueError(
                f"trace of {t} entries needs {length} slots/SQ but "
                f"sq_depth={cfg.sq_depth}"
            )
        # Host-side numpy at trace-build time, not a jit sort plan.
        # repro-lint: disable=RL003
        order = np.argsort(times_us, kind="stable")
        sub = np.full((q, length), FAR, np.float32)
        lb = np.zeros((q, length), np.int32)
        op = np.zeros((q, length), np.int32)
        va = np.zeros((q, length), bool)
        j = np.arange(t)
        rows, cols = j % q, j // q
        sub[rows, cols] = times_us[order]
        lb[rows, cols] = lbas[order]
        op[rows, cols] = opcodes[order]
        va[rows, cols] = True
        def tup(a):
            return tuple(tuple(r) for r in a.tolist())

        return TraceReplay(
            io_depth=length, submit=tup(sub), lba=tup(lb), ops=tup(op),
            mask=tup(va),
        )

    @property
    def num_requests(self) -> int:
        return int(np.sum(np.asarray(self.mask)))

    def sharded(self, num_shards: int) -> "TraceReplay":
        """Stripe the trace across ``num_shards`` array drives."""
        if num_shards < 1:
            raise ValueError(f"num_shards={num_shards} must be >= 1")
        return dataclasses.replace(self, num_shards=num_shards)

    def prefill(self, cfg, ssd, salt=0) -> Prefill:
        sub = jnp.asarray(self.submit, jnp.float32)
        q, length = sub.shape
        if q != cfg.num_sqs:
            raise ValueError(
                f"trace was built for {q} SQs, engine has {cfg.num_sqs}"
            )
        req_id = (
            jnp.arange(q, dtype=jnp.int32)[:, None] * length
            + jnp.arange(length, dtype=jnp.int32)[None, :]
        )
        valid = jnp.asarray(self.mask, bool)
        if self.num_shards > 1:
            # ``from_trace`` dealt time-sorted entry i to cell
            # (row=i % q, col=i // q); reconstruct i and keep only this
            # drive's stripe (i % M == salt). Column order ascends in
            # time within each row, so the surviving entries stay
            # ring-sorted and arrival times are untouched.
            trace_idx = (
                jnp.arange(length, dtype=jnp.int32)[None, :] * q
                + jnp.arange(q, dtype=jnp.int32)[:, None]
            )
            mine = trace_idx % jnp.int32(self.num_shards) == jnp.asarray(
                salt, jnp.int32
            )
            valid = valid & mine
        return Prefill(
            submit=sub,
            opcode=jnp.asarray(self.ops, jnp.int32),
            lba=jnp.asarray(self.lba, jnp.int32),
            nblocks=jnp.ones((q, length), jnp.int32),
            req_id=req_id,
            valid=valid,
            tenant=jnp.broadcast_to(
                self.tenant_of_sq(
                    jnp.arange(q, dtype=jnp.int32), cfg, salt
                )[:, None],
                (q, length),
            ),
        )

    def next_submit(self, new_req, done, valid, anchor, cfg, ssd,
                    salt=0) -> Tuple[jax.Array, jax.Array]:
        return jnp.full_like(done, FAR), jnp.zeros_like(valid)
