"""Make the repo root importable so tests can reach ``tools.repro_lint``.

The runtime package lives under ``src/`` (on PYTHONPATH per ROADMAP's
tier-1 command); the developer tooling lives at the repo root and is not
installed anywhere, so pin the root onto ``sys.path`` here.
"""
import sys
from pathlib import Path

_ROOT = str(Path(__file__).resolve().parents[1])
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
