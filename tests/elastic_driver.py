"""Subprocess driver for the elastic multi-device training test.

Phase A: train 4 steps on a (data=4, model=2) mesh, checkpoint, "crash".
Phase B: resume on a (data=2, model=2) mesh (simulating losing half the
data-parallel capacity) via reshard-on-load; train 2 more steps.

Run as:  python tests/elastic_driver.py <phase> <ckpt_dir>
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro import checkpoint, configs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib


def build(cfg):
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    opt = opt_lib.init_opt_state(params)
    return params, opt


def shardings_for(cfg, params, opt, mesh):
    p_axes = transformer.model_axes(cfg)
    p_sh = shd.sharding_tree(p_axes, shd.DEFAULT_RULES, mesh, params)
    o_sh = {
        "m": shd.sharding_tree(p_axes, shd.DEFAULT_RULES, mesh, opt["m"]),
        "v": shd.sharding_tree(p_axes, shd.DEFAULT_RULES, mesh, opt["v"]),
        "step": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()
        ),
    }
    return p_sh, o_sh


def train_steps(cfg, mesh, params, opt, start, n, ckpt_dir):
    ocfg = opt_lib.AdamWConfig(lr=1e-3)

    def step(params, opt_state, tokens, labels):
        def loss(p):
            return transformer.loss_fn(p, cfg, tokens, labels)

        l, g = jax.value_and_grad(loss)(params)
        p2, o2, _ = opt_lib.apply_updates(params, g, opt_state, ocfg)
        return p2, o2, l

    jstep = jax.jit(step)
    losses = []
    with mesh, shd.use_rules(mesh, shd.DEFAULT_RULES):
        for i in range(start, start + n):
            b = data_lib.synth_batch(i, 8, 64, cfg.vocab)
            params, opt, l = jstep(
                params, opt, jnp.asarray(b["tokens"]),
                jnp.asarray(b["labels"]),
            )
            losses.append(float(l))
    checkpoint.save(ckpt_dir, start + n, {"params": params, "opt": opt})
    return params, opt, losses


def main():
    phase, ckpt_dir = sys.argv[1], sys.argv[2]
    cfg = configs.get_config("yi-34b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, loss_chunk=32,
    )
    if phase == "A":
        mesh = make_mesh(4, 2)
        params, opt = build(cfg)
        p_sh, o_sh = shardings_for(cfg, params, opt, mesh)
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, o_sh)
        params, opt, losses = train_steps(
            cfg, mesh, params, opt, 0, 4, ckpt_dir
        )
        print("PHASE_A_LOSSES", losses)
    else:
        mesh = make_mesh(2, 2)  # elastic downsize: half the data capacity
        tmpl_p, tmpl_o = build(cfg)
        p_sh, o_sh = shardings_for(cfg, tmpl_p, tmpl_o, mesh)
        state, manifest = checkpoint.load(
            ckpt_dir, {"params": tmpl_p, "opt": tmpl_o},
            shardings={"params": p_sh, "opt": o_sh},
        )
        assert manifest["step"] == 4
        params, opt = state["params"], state["opt"]
        # Verify the resumed params actually live on the NEW mesh.
        leaf = jax.tree.leaves(params)[0]
        assert leaf.sharding.mesh.devices.size == 4, leaf.sharding
        params, opt, losses = train_steps(
            cfg, mesh, params, opt, 4, 2, ckpt_dir
        )
        print("PHASE_B_LOSSES", losses)
    print("OK")


if __name__ == "__main__":
    main()
