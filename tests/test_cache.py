"""Stage-0 GPU page-cache tests: lookup/insert semantics, engine
hit-chase accounting, client filtering, and hit-rate -> IOPS monotonicity
(the fig22 contract)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_mod
from repro.core import engine
from repro.core.cache import CacheState
from repro.core.client import StorageClient
from repro.core.types import CacheConfig, EngineConfig, SSDConfig
from repro import workloads

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 14)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)


def _cc(**kw):
    base = dict(enabled=True, num_sets=64, ways=2, hit_us=0.5, chase=2)
    base.update(kw)
    return CacheConfig(**base)


# ---------------------------------------------------------------------------
# Tag-array unit semantics.
# ---------------------------------------------------------------------------

def test_insert_then_lookup_hits():
    cc = _cc()
    st = CacheState.init(cc)
    lba = jnp.asarray([5, 69, 1000], jnp.int32)
    ones = jnp.ones((3,), bool)
    assert not bool(cache_mod.lookup(st, lba, ones, cc).any())
    st = cache_mod.insert(st, lba, ones, cc)
    assert bool(cache_mod.lookup(st, lba, ones, cc).all())
    # Other addresses still miss.
    other = jnp.asarray([6, 70], jnp.int32)
    assert not bool(
        cache_mod.lookup(st, other, jnp.ones((2,), bool), cc).any()
    )


def test_fifo_eviction_within_set():
    """W+1 distinct blocks mapping to one set evict the oldest."""
    cc = _cc(num_sets=4, ways=2)
    st = CacheState.init(cc)
    seq = [0, 4, 8]  # all map to set 0
    for b in seq:
        st = cache_mod.insert(
            st, jnp.asarray([b], jnp.int32), jnp.ones((1,), bool), cc
        )
    hit = cache_mod.lookup(
        st, jnp.asarray(seq, jnp.int32), jnp.ones((3,), bool), cc
    )
    assert not bool(hit[0])          # oldest evicted
    assert bool(hit[1]) and bool(hit[2])


def test_insert_skips_already_present():
    """Re-inserting a resident block must not burn a victim way."""
    cc = _cc(num_sets=4, ways=2)
    st = CacheState.init(cc)
    one = jnp.ones((1,), bool)
    st = cache_mod.insert(st, jnp.asarray([0], jnp.int32), one, cc)
    st = cache_mod.insert(st, jnp.asarray([4], jnp.int32), one, cc)
    st = cache_mod.insert(st, jnp.asarray([0], jnp.int32), one, cc)  # dup
    hit = cache_mod.lookup(
        st, jnp.asarray([0, 4], jnp.int32), jnp.ones((2,), bool), cc
    )
    assert bool(hit.all())


def test_readahead_fills_sequential_blocks():
    cc = _cc(num_sets=64, ways=2, readahead=3)
    st = CacheState.init(cc)
    st = cache_mod.insert(
        st, jnp.asarray([10], jnp.int32), jnp.ones((1,), bool), cc
    )
    probe = jnp.asarray([10, 11, 12, 13, 14], jnp.int32)
    hit = np.asarray(
        cache_mod.lookup(st, probe, jnp.ones((5,), bool), cc)
    )
    assert hit[:4].all() and not hit[4]


def test_serve_prices_hits_at_gpu_latency():
    cc = _cc()
    st = cache_mod.insert(
        CacheState.init(cc), jnp.asarray([7], jnp.int32),
        jnp.ones((1,), bool), cc,
    )
    lba = jnp.asarray([7, 8], jnp.int32)
    t = jnp.asarray([100.0, 100.0], jnp.float32)
    hit, done = cache_mod.serve(st, lba, jnp.ones((2,), bool), t, cc)
    assert bool(hit[0]) and not bool(hit[1])
    assert float(done[0]) == pytest.approx(100.5)
    assert float(done[1]) == 0.0


# ---------------------------------------------------------------------------
# Engine integration.
# ---------------------------------------------------------------------------

def test_disabled_cache_changes_nothing():
    """cache.enabled=False is the exact pre-cache engine (state pytree
    carries None and metrics count zero hits)."""
    wl = workloads.ZipfClosedLoop(io_depth=32, theta=0.9)
    out = engine.simulate(CFG, SSD, wl, rounds=16)
    assert out.cache is None
    assert float(out.metrics.cache_hits) == 0.0
    assert float(out.metrics.hit_rate()) == 0.0


def test_zipf_hit_rate_amplifies_iops_monotonically():
    """fig22's acceptance contract: delivered IOPS increase monotonically
    with the stage-0 hit rate as the cache grows."""
    wl = workloads.ZipfClosedLoop(io_depth=64, theta=0.9)
    rows = []
    for sets in [0, 16, 256, 1024]:
        cc = CacheConfig(enabled=sets > 0, num_sets=max(sets, 1), ways=4,
                         hit_us=0.5, chase=2)
        out = engine.simulate(CFG.replace(cache=cc), SSD, wl, rounds=24)
        m = out.metrics
        rows.append((float(m.hit_rate()), float(m.iops())))
    by_hit = sorted(rows)
    hits = [r[0] for r in by_hit]
    iops = [r[1] for r in by_hit]
    assert hits[0] == 0.0 and hits[-1] > 0.3
    assert all(a <= b + 1e-3 for a, b in zip(iops, iops[1:])), rows


def test_hit_completions_enter_metrics():
    """Hits count as completed requests at hit_us latency (histogram mass
    equals completed, including the cache-served requests)."""
    cc = _cc(num_sets=1024, ways=4)
    wl = workloads.ZipfClosedLoop(io_depth=32, theta=0.9)
    out = engine.simulate(CFG.replace(cache=cc), SSD, wl, rounds=24)
    m = out.metrics
    assert float(m.cache_hits) > 0.0
    assert float(jnp.sum(m.lat_hist)) == pytest.approx(float(m.completed))
    assert float(m.completed) > float(m.fetched)  # hits never fetched


# ---------------------------------------------------------------------------
# Client integration.
# ---------------------------------------------------------------------------

def test_client_repeat_reads_hit():
    cfg = EngineConfig(num_units=4, fetch_width=64, cache=_cc())
    client = StorageClient(SSD, cfg)
    flash = jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, 8)
    )
    lba = (jnp.arange(64, dtype=jnp.int32) * 3) % SSD.num_blocks
    st = client.init_state()
    st, data1, done1 = client.read(st, flash, lba, jnp.float32(0))
    t1 = float(jnp.max(done1))
    st, data2, done2 = client.read(st, flash, lba, jnp.float32(t1))
    # Second pass: all hits at GPU-local latency; data still correct.
    np.testing.assert_allclose(
        np.asarray(done2), t1 + cfg.cache.hit_us, rtol=1e-6
    )
    assert float(jnp.min(done1)) >= SSD.l_min_us - 1e-3
    np.testing.assert_array_equal(np.asarray(data2), np.asarray(data1))


def test_client_write_allocates_cache():
    cfg = EngineConfig(num_units=4, fetch_width=64, cache=_cc())
    client = StorageClient(SSD, cfg)
    flash = jnp.zeros((SSD.num_blocks, 8))
    lba = jnp.arange(16, dtype=jnp.int32)
    data = jnp.ones((16, 8))
    st = client.init_state()
    st, flash, wdone = client.write(st, flash, data, lba, jnp.float32(0))
    t1 = float(jnp.max(wdone))
    st, rdata, rdone = client.read(st, flash, lba, jnp.float32(t1))
    np.testing.assert_allclose(
        np.asarray(rdone), t1 + cfg.cache.hit_us, rtol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(rdata), np.asarray(data))


def test_cache_config_validation():
    with pytest.raises(ValueError, match="num_sets"):
        CacheConfig(num_sets=0)
    with pytest.raises(ValueError, match="chase"):
        CacheConfig(chase=0)
    with pytest.raises(ValueError, match="cq_coalesce_n"):
        from repro.core.types import QPConfig

        QPConfig(cq_coalesce_n=0)
