"""The unified ``StorageClient.submit(ops)`` op API.

Pins the redesign's contract: the six legacy entry points (read, write,
read_array, write_array, read_striped, read_replicated) are thin
wrappers over ``submit``/``submit_array``/``submit_striped`` and must
stay *bit-exact* against op batches built by hand — including tenant
QoS classes and remote switched-fabric configs. Also covers mixed
read/write batches, the ``write_replicated`` fan-out (completion =
max over replicas, one hand-built grid), and the *removal* of the
ring-less ``DevicePipeline.fetch_direct``/``submit_direct`` shortcuts
(deprecated with warnings since PR 7, gone in PR 9 — the underscore
test-only names remain).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import StorageClient
from repro.core.device import DevicePipeline, make_direct_batch
from repro.core.segops import segment_rank
from repro.core.types import (
    OP_WRITE,
    CacheConfig,
    EngineConfig,
    FabricConfig,
    SSDConfig,
    StorageOps,
)

SSD = SSDConfig(t_max_iops=1e6, l_min_us=20.0, n_instances=32,
                num_blocks=1 << 10)
LOCAL = EngineConfig(num_sqs=8, sq_depth=64, num_units=4, fetch_width=32)
REMOTE_QOS = LOCAL.replace(
    fabric=FabricConfig(
        remote=True, tx_bytes_per_us=1000.0, rx_bytes_per_us=1000.0,
        rtt_us=2.0, wire_txn_us=0.2, mtu_batch=4, mtu_timeout_us=5.0,
        switch_bytes_per_us=2000.0, switch_fanin=2,
        qos_weights=(2.0, 1.0),
    )
)
CACHED = LOCAL.replace(
    cache=CacheConfig(enabled=True, num_sets=16, ways=2, readahead=1)
)
CONFIGS = [("local", LOCAL), ("remote_qos", REMOTE_QOS),
           ("cached", CACHED)]


def _flash(n=1 << 10, w=16):
    return (
        jnp.arange(n, dtype=jnp.float32)[:, None]
        + jnp.arange(w, dtype=jnp.float32)[None, :] * 1e-3
    )


def _batch(n=48, seed=0):
    rng = np.random.default_rng(seed)
    lba = jnp.asarray(rng.integers(0, 1 << 10, n), jnp.int32)
    t = jnp.asarray(rng.uniform(0.0, 5.0, n), jnp.float32)
    valid = jnp.asarray(rng.random(n) > 0.1)
    tenant = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    return lba, t, valid, tenant


@pytest.mark.parametrize("name,ecfg", CONFIGS)
def test_read_is_bit_exact_wrapper_over_submit(name, ecfg):
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    lba, t, valid, tenant = _batch()
    st1, data1, done1 = client.read(
        client.init_state(), flash, lba, t, valid, tenant=tenant
    )
    ops = StorageOps.make(lba, t, tenant=tenant, valid=valid)
    st2, _, data2, done2 = client.submit(
        client.init_state(), flash, ops, with_data=True
    )
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    np.testing.assert_array_equal(np.asarray(data1), np.asarray(data2))
    for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name,ecfg", CONFIGS)
def test_write_is_bit_exact_wrapper_over_submit(name, ecfg):
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    lba, t, valid, tenant = _batch(seed=1)
    data = jnp.ones((48, 16)) * jnp.arange(48)[:, None]
    st1, fl1, done1 = client.write(
        client.init_state(), flash, data, lba, t, valid, tenant=tenant
    )
    ops = StorageOps.make(
        lba, t, opcode=OP_WRITE, tenant=tenant, valid=valid
    )
    st2, fl2, _, done2 = client.submit(
        client.init_state(), flash, ops, data=data
    )
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    np.testing.assert_array_equal(np.asarray(fl1), np.asarray(fl2))


@pytest.mark.parametrize("name,ecfg", [CONFIGS[0], CONFIGS[1]])
def test_array_wrappers_bit_exact(name, ecfg):
    m, n = 2, 24
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    rng = np.random.default_rng(2)
    lba = jnp.asarray(rng.integers(0, 1 << 10, (m, n)), jnp.int32)
    t = jnp.asarray(rng.uniform(0.0, 3.0, (m, n)), jnp.float32)
    valid = jnp.asarray(rng.random((m, n)) > 0.1)
    tenant = jnp.asarray(rng.integers(0, 2, (m, n)), jnp.int32)
    ops = StorageOps.make(lba, t, tenant=tenant, valid=valid)

    st1, data1, done1 = client.read_array(
        client.init_array_state(m), flash, lba, t, valid, tenant=tenant
    )
    st2, _, data2, done2 = client.submit_array(
        client.init_array_state(m), flash, ops, with_data=True
    )
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    np.testing.assert_array_equal(np.asarray(data1), np.asarray(data2))

    wdata = jnp.ones((m, n, 16)) * 7.0
    wops = StorageOps.make(
        lba, t, opcode=OP_WRITE, tenant=tenant, valid=valid
    )
    st3, fl3, done3 = client.write_array(
        client.init_array_state(m), flash, wdata, lba, t, valid,
        tenant=tenant,
    )
    st4, fl4, _, done4 = client.submit_array(
        client.init_array_state(m), flash, wops, data=wdata
    )
    np.testing.assert_array_equal(np.asarray(done3), np.asarray(done4))
    np.testing.assert_array_equal(np.asarray(fl3), np.asarray(fl4))


@pytest.mark.parametrize("name,ecfg", [CONFIGS[0], CONFIGS[1]])
def test_read_striped_bit_exact(name, ecfg):
    m = 3
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    lba, t, valid, tenant = _batch(n=29, seed=3)   # ragged tail stripe
    st1, data1, done1 = client.read_striped(
        client.init_array_state(m), flash, lba, t, valid,
        stripe_width=2, tenant=tenant,
    )
    ops = StorageOps.make(lba, t, tenant=tenant, valid=valid)
    st2, _, data2, done2 = client.submit_striped(
        client.init_array_state(m), flash, ops, stripe_width=2,
        with_data=True,
    )
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    np.testing.assert_array_equal(np.asarray(data1), np.asarray(data2))


@pytest.mark.parametrize("name,ecfg", [CONFIGS[0], CONFIGS[1]])
def test_read_replicated_r1_bit_exact_vs_submit_array(name, ecfg):
    """With replicas=1 routing is deterministic (drive = lba % M), so
    the wrapper must equal a hand-scattered submit_array op batch."""
    m, n = 2, 20
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    lba, t, valid, tenant = _batch(n=n, seed=4)
    st1, data1, done1 = client.read_replicated(
        client.init_array_state(m), flash, lba, t, valid, replicas=1,
        tenant=tenant,
    )

    drive = jnp.where(valid, lba % m, m)
    rank = segment_rank(drive)
    row = jnp.clip(drive, 0, m - 1)
    col = jnp.where(valid, rank, n)

    def scat(x, fill, dtype):
        base = jnp.full((m, n), fill, dtype)
        return base.at[row, col].set(x, mode="drop")

    ops = StorageOps(
        opcode=scat(jnp.zeros((n,), jnp.int32), 0, jnp.int32),
        lba=scat(lba, 0, jnp.int32),
        t_submit=scat(t, 0.0, jnp.float32),
        tenant=scat(tenant, 0, jnp.int32),
        valid=scat(valid, False, bool),
    )
    _, _, _, done2d = client.submit_array(
        client.init_array_state(m), flash, ops
    )
    done2 = jnp.where(
        valid, done2d[row, jnp.clip(col, 0, n - 1)], 0.0
    )
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    np.testing.assert_array_equal(
        np.asarray(data1), np.asarray(flash[jnp.where(valid, lba, 0)])
    )


@pytest.mark.parametrize("name,ecfg", [CONFIGS[0], CONFIGS[1]])
def test_write_replicated_bit_exact_vs_submit_array(name, ecfg):
    """The R-way write fan-out must equal one hand-scattered
    submit_array over the same (M, N) grid: every request lands on all
    R replica drives ``(lba + r) % M`` and completes at the max over
    its replica completions."""
    m, n, r = 3, 20, 2
    client = StorageClient(SSD, ecfg)
    flash = _flash()
    lba, t, valid, tenant = _batch(n=n, seed=5)
    data = jnp.ones((n, 16)) * jnp.arange(n)[:, None]
    st1, fl1, done1 = client.write_replicated(
        client.init_array_state(m), flash, data, lba, t, valid,
        replicas=r, tenant=tenant,
    )

    # Hand-build the identical fan-out grid: request-major flattened
    # (N*R,) candidates, ranked into per-drive slots.
    cand = (lba[:, None] + jnp.arange(r, dtype=jnp.int32)[None, :]) % m
    valid_rep = jnp.repeat(valid, r)
    drive = jnp.where(valid_rep, cand.reshape(-1), m)
    rank = segment_rank(drive)
    row = jnp.clip(drive, 0, m - 1)
    col = jnp.where(valid_rep, rank, n * r)

    def scat(x, fill, dtype):
        base = jnp.full((m, n), fill, dtype)
        return base.at[row, col].set(x, mode="drop")

    ops = StorageOps(
        opcode=jnp.full((m, n), OP_WRITE, jnp.int32),
        lba=scat(jnp.repeat(lba, r), 0, jnp.int32),
        t_submit=scat(jnp.repeat(t, r), 0.0, jnp.float32),
        tenant=scat(jnp.repeat(tenant, r), 0, jnp.int32),
        valid=scat(valid_rep, False, bool),
    )
    st2, _, _, done2d = client.submit_array(
        client.init_array_state(m), flash, ops
    )
    done_rep = done2d[row, jnp.clip(col, 0, n - 1)].reshape(n, r)
    done2 = jnp.where(valid, jnp.max(done_rep, axis=1), 0.0)
    np.testing.assert_array_equal(np.asarray(done1), np.asarray(done2))
    for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # Durability: the store holds each valid request's block once, and
    # a replica read of any valid lba returns it.
    np.testing.assert_array_equal(
        np.asarray(fl1[jnp.where(valid, lba, 1023)][valid]),
        np.asarray(data[valid]),
    )
    # Completion is the max over replicas: no replica finishes later.
    assert bool(jnp.all(done1[:, None] >= jnp.where(
        valid[:, None], done_rep, 0.0
    )))


def test_write_replicated_r1_matches_plain_write_placement():
    """R=1 degenerates to single-copy placement at drive lba % M."""
    m, n = 2, 12
    client = StorageClient(SSD, LOCAL)
    flash = _flash()
    lba = jnp.arange(n, dtype=jnp.int32)
    data = jnp.full((n, 16), 2.5)
    _, fl, done = client.write_replicated(
        client.init_array_state(m), flash, data, lba, replicas=1
    )
    assert bool(jnp.all(done > 0.0))
    np.testing.assert_array_equal(np.asarray(fl[:n]), np.asarray(data))


def test_mixed_batch_reads_observe_writes():
    """One submit may mix opcodes/tenants: the functional gather sees
    this batch's writes, and every valid op completes."""
    client = StorageClient(SSD, LOCAL)
    flash = _flash()
    n = 16
    lba = jnp.arange(n, dtype=jnp.int32)
    opcode = jnp.asarray([OP_WRITE, 0] * (n // 2), jnp.int32)
    tenant = jnp.asarray([1, 0] * (n // 2), jnp.int32)
    ops = StorageOps.make(lba, 0.0, opcode=opcode, tenant=tenant)
    data = jnp.full((n, 16), -5.0)
    _, flash2, out, done = client.submit(
        client.init_state(), flash, ops, data=data, with_data=True
    )
    # Write slots landed; the batch-level gather reflects them.
    np.testing.assert_array_equal(
        np.asarray(flash2[0]), np.full((16,), -5.0)
    )
    np.testing.assert_array_equal(
        np.asarray(out[::2]), np.full((n // 2, 16), -5.0)
    )
    assert float(jnp.min(done)) > 0.0


def test_wrapper_kwargs_are_uniform():
    """Every entry point accepts the same (t_submit=0.0, valid=None,
    tenant=0) keyword surface — the API-unification satellite."""
    import inspect

    for name in ("read", "write", "read_array", "write_array",
                 "read_striped", "read_replicated"):
        params = inspect.signature(
            getattr(StorageClient, name)
        ).parameters
        assert params["t_submit"].default == 0.0, name
        assert params["valid"].default is None, name
        assert params["tenant"].default == 0, name


def test_direct_aliases_removed():
    """The deprecated ring-less public aliases are gone (PR 9); the
    underscore test-only entry points still work via the op API's
    direct batch builder."""
    from repro.core.types import PlatformModel

    assert not hasattr(DevicePipeline, "fetch_direct")
    assert not hasattr(DevicePipeline, "submit_direct")

    pipe = DevicePipeline(LOCAL, SSD, PlatformModel())
    t = jnp.zeros((8,), jnp.float32)
    valid = jnp.ones((8,), bool)
    batch = make_direct_batch(jnp.arange(8, dtype=jnp.int32), t, valid)
    _, res = pipe._submit_direct(pipe.init_state(), batch)
    assert bool(jnp.all(res.target > 0.0))
