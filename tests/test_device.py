"""Unified-pipeline tests: engine/client parity + config validation.

The refactor's contract: there is ONE device cost model (device.py), and
both consumers run the identical SQ -> pipeline -> CQ queue-pair path —
``engine_round`` over its persistent rings, ``StorageClient`` over
per-call rings. The parity tests prove both produce bit-identical
virtual-time state/completions for the same request stream.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, frontend
from repro.core.client import ClientState, StorageClient
from repro.core.device import DevicePipeline
from repro.core.frontend import SQRings
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    SSDConfig,
    WorkloadConfig,
)

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)


def test_client_read_equals_ring_pipeline_composition():
    """StorageClient.read == SQ submit + ring fetch + the shared
    ``process`` with a CQ (the exact stages engine_round invokes) on an
    identical request stream."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    plat = PlatformModel()
    pipe = DevicePipeline(cfg, SSD, plat)
    client = StorageClient(SSD, cfg, plat)

    n = 512
    lba = (jnp.arange(n, dtype=jnp.int32) * 37) % SSD.num_blocks
    t = jnp.float32(3.0)
    flash = jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, 8)
    )
    cstate = ClientState.init(SSD, 4)
    cstate2, data, done_client = client.read(cstate, flash, lba, t)

    # Replicate by hand: deal SQEs, ring-fetch, shared process + CQ reap.
    q = cfg.num_sqs
    rings = SQRings.empty(q, cfg.sq_depth)
    rings = frontend.submit(
        rings, frontend.deal_sqs(n, cfg), jnp.full((n,), t),
        jnp.zeros((n,), jnp.int32), lba, jnp.ones((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32), jnp.arange(n, dtype=jnp.int32),
        jnp.ones((n,), bool),
    )
    dstate = pipe.init_state()
    cq = pipe.init_cq()
    rings, disp_time, batch, fetch_done = frontend.fetch_distributed(
        rings, t, dstate.disp_time, cfg, plat
    )
    dstate = dataclasses.replace(dstate, disp_time=disp_time)
    unit = jnp.arange(q * cfg.fetch_width, dtype=jnp.int32) // (
        q * cfg.fetch_width // cfg.num_units
    )
    dstate, cq, res = pipe.process(dstate, batch, fetch_done, unit, cq)
    done_manual = (
        jnp.zeros((n,), jnp.float32)
        .at[jnp.where(batch.valid, batch.req_id, n)]
        .set(res.reaped, mode="drop")
    )

    np.testing.assert_array_equal(
        np.asarray(done_client), np.asarray(done_manual)
    )
    np.testing.assert_array_equal(
        np.asarray(cstate2.dev.tstate.busy_until),
        np.asarray(dstate.tstate.busy_until),
    )
    np.testing.assert_array_equal(
        np.asarray(cstate2.dev.dsa_time), np.asarray(dstate.dsa_time)
    )
    np.testing.assert_array_equal(np.asarray(data[:, 0]), np.asarray(lba))


@pytest.mark.parametrize("mode", ["aggregated", "per_request"])
@pytest.mark.parametrize("batched", [True, False])
def test_engine_round_prices_through_shared_pipeline(mode, batched):
    """One engine_round leaves the device in exactly the state produced by
    frontend fetch + the shared DevicePipeline.process — for every
    timing-mode/datapath combination."""
    cfg = EngineConfig(
        num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
        workers_per_unit=2, mode=mode, batched_datapath=batched,
        emulate_data=False, num_bufs=512,
    )
    wl = WorkloadConfig(io_depth=16)
    plat = PlatformModel()
    pipe = DevicePipeline(cfg, SSD, plat)

    st = engine.init_state(cfg, SSD, wl)
    st = dataclasses.replace(st, clock=jnp.float32(50.0))  # all visible
    out = engine.engine_round(st, cfg, SSD, wl, plat)

    # Replicate stage 1 (ring fetch) + stages 2-3 (shared pipeline) by hand.
    _, disp_time, batch, fetch_done = frontend.fetch_distributed(
        st.rings, st.clock, st.device.disp_time, cfg, plat
    )
    n = batch.valid.shape[0]
    unit = jnp.arange(n, dtype=jnp.int32) // (
        cfg.num_sqs * cfg.fetch_width // cfg.num_units
    )
    dev = dataclasses.replace(st.device, disp_time=disp_time)
    dev, _, res = pipe.process(dev, batch, fetch_done, unit, st.cq)

    for got, want in [
        (out.device.tstate.busy_until, dev.tstate.busy_until),
        (out.device.disp_time, dev.disp_time),
        (out.device.dsa_time, dev.dsa_time),
        (out.device.work_time, dev.work_time),
        (out.device.lock_time, dev.lock_time),
        (out.device.map_time, dev.map_time),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Metrics derive from the same per-request completions.
    e2e = jnp.where(batch.valid, res.done - batch.arrival, 0.0)
    np.testing.assert_allclose(
        float(out.metrics.sum_e2e), float(jnp.sum(e2e)), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(out.metrics.last_completion),
        float(jnp.max(jnp.where(batch.valid, res.done, 0.0))),
        rtol=1e-6,
    )


def test_latency_histogram_consistency():
    """Histogram mass equals completed count and percentiles are ordered."""
    cfg = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                       emulate_data=False, num_bufs=512)
    st = engine.simulate(cfg, SSD, WorkloadConfig(io_depth=64), rounds=48)
    m = st.metrics
    assert float(jnp.sum(m.lat_hist)) == pytest.approx(float(m.completed))
    p50, p95, p99 = float(m.p50_us()), float(m.p95_us()), float(m.p99_us())
    assert 50.0 * 0.8 <= p50 <= float(m.avg_e2e_us()) * 2.0
    assert p50 <= p95 <= p99


def test_multi_device_array_aggregates():
    """An M-drive vmapped array multiplies sustained IOPS ~M-fold."""
    cfg = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                       emulate_data=False, num_bufs=512)
    wl = WorkloadConfig(io_depth=64)
    one = engine.simulate(cfg, SSD, wl, rounds=32)
    arr = engine.simulate(cfg, SSD, wl, rounds=32, num_devices=4)
    solo = float(one.metrics.iops())
    agg = float(engine.aggregate_iops(arr))
    assert arr.metrics.completed.shape == (4,)
    assert agg == pytest.approx(4 * solo, rel=0.1)
    # Per-device streams are salted differently -> distinct request content
    # (timing is content-independent under round-robin routing, so latency
    # legitimately matches across drives).
    assert np.any(np.asarray(arr.rings.lba[0]) != np.asarray(arr.rings.lba[1]))


def test_client_state_shapes_match_engine_for_all_frontends():
    """init_state derives the exact device-state shapes engine_round uses —
    including centralized frontends (one dispatcher regardless of
    num_units) and baseline datapaths (worker lanes matter)."""
    import jax

    for cfg in [
        EngineConfig(num_units=4, fetch_width=64, batched_datapath=False,
                     workers_per_unit=4),
        EngineConfig(frontend="centralized", num_units=4, fetch_width=64),
    ]:
        cstate = StorageClient(SSD, cfg).init_state()
        est = engine.init_state(cfg, SSD, WorkloadConfig(io_depth=4))
        shapes_ok = jax.tree.map(
            lambda a, b: a.shape == b.shape, cstate.dev, est.device
        )
        assert all(jax.tree.leaves(shapes_ok)), (cfg.frontend, shapes_ok)


def test_client_striped_array_read():
    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    m, n = 4, 1024
    state = client.init_array_state(m)
    flash = jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, 8)
    )
    lba = (jnp.arange(n, dtype=jnp.int32) * 13) % SSD.num_blocks
    state, data, done = client.read_striped(state, flash, lba, jnp.float32(0))
    np.testing.assert_array_equal(np.asarray(data[:, 0]), np.asarray(lba))
    lat = np.asarray(done)
    assert lat.shape == (n,)
    assert (lat >= 50.0 - 1e-3).all()
    # M drives in parallel finish the batch ~M times sooner than one drive.
    solo_state = client.init_state()
    _, _, solo_done = client.read(solo_state, flash, lba, jnp.float32(0))
    assert float(jnp.max(done)) < 0.5 * float(jnp.max(solo_done))


def test_client_striped_read_ragged_matches_read_oracle():
    """Regression: N % M != 0 used to raise — now the tail stripe pads
    with invalid slots and every drive's completions match a plain
    per-drive ``read`` bit-exactly, in the original request order."""
    import jax

    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    m, n = 4, 1003  # ragged tail: 1003 = 4*250 + 3
    state = client.init_array_state(m)
    flash = jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, 8)
    )
    lba = (jnp.arange(n, dtype=jnp.int32) * 13) % SSD.num_blocks
    state2, data, done = client.read_striped(state, flash, lba,
                                             jnp.float32(0))
    assert done.shape == (n,)
    np.testing.assert_array_equal(np.asarray(data[:, 0]), np.asarray(lba))
    for d in range(m):
        rows = np.arange(n)[np.arange(n) % m == d]
        st_d = ClientState(dev=jax.tree.map(lambda x: x[d], state.dev))
        _, _, done_d = client.read(st_d, flash, lba[rows], jnp.float32(0))
        np.testing.assert_array_equal(
            np.asarray(done)[rows], np.asarray(done_d)
        )


def test_client_striped_read_stripe_width():
    """stripe_width=W engages only the first W drives; narrower stripes
    serialize more and never finish sooner."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    m, n = 4, 512
    state = client.init_array_state(m)
    flash = jnp.ones((SSD.num_blocks, 8))
    lba = (jnp.arange(n, dtype=jnp.int32) * 7) % SSD.num_blocks
    prev = None
    for w in (m, 2, 1):
        _, _, done = client.read_striped(
            state, flash, lba, jnp.float32(0), stripe_width=w
        )
        span = float(jnp.max(done))
        if prev is not None:
            assert span > prev
        prev = span
    with pytest.raises(ValueError, match="stripe_width"):
        client.read_striped(state, flash, lba, jnp.float32(0),
                            stripe_width=m + 1)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="divisible"):
        EngineConfig(num_sqs=10, num_units=4)
    with pytest.raises(ValueError, match="fetch_width"):
        EngineConfig(sq_depth=64, fetch_width=128)
    with pytest.raises(ValueError, match="frontend"):
        EngineConfig(frontend="diagonal")
    # Centralized frontends always run one dispatcher: units need not divide.
    EngineConfig(num_sqs=10, num_units=4, frontend="centralized")
