"""Dry-run pipeline integration: one real cell lowers+compiles on the
production mesh in a subprocess (the 512-virtual-device env must be set
before jax initializes, hence the subprocess)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [("starcoder2-3b", "train_4k")])
def test_dryrun_cell_compiles(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "single",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}__{shape}__single.json"))
    assert rec["status"] == "ok", rec
    assert rec["chips"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    # Useful-compute sanity: within (0, 1.5] of the 6*N*D analytic bound.
    assert 0.05 < rec["useful_compute_ratio"] <= 1.5


def test_specs_build_for_every_cell():
    """input_specs + abstract trees construct for all 40 assigned cells
    (no device allocation, no mesh needed)."""
    from repro import configs
    from repro.launch import specs as specs_lib

    for arch, shape in configs.cells():
        if not configs.runnable(arch, shape):
            continue
        sp = specs_lib.input_specs(arch, shape)
        assert "params" in sp and "batch" in sp
        n_leaves = len(__import__("jax").tree.leaves(sp["params"]))
        assert n_leaves > 3, (arch, shape)
