"""Elastic fault tolerance, executed on a real (virtual-8-device) mesh:
train sharded on (data=4, model=2), crash, resume resharded on
(data=2, model=2) from the checkpoint — the supervisor's
"elastic_downsize + reshard-on-load" action end to end."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRIVER = os.path.join(REPO, "tests", "elastic_driver.py")


def _run(phase, ckpt_dir):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, DRIVER, phase, str(ckpt_dir)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    return out.stdout


@pytest.mark.slow
def test_elastic_downsize_resume(tmp_path):
    a = _run("A", tmp_path)
    assert "PHASE_A_LOSSES" in a and "OK" in a
    b = _run("B", tmp_path)
    assert "PHASE_B_LOSSES" in b and "OK" in b
    # Loss continues to decrease across the elastic restart. Per-batch
    # losses are noisy at these tiny step counts, so compare trajectory
    # means rather than two individual batches.
    la = eval(a.split("PHASE_A_LOSSES", 1)[1].splitlines()[0])
    lb = eval(b.split("PHASE_B_LOSSES", 1)[1].splitlines()[0])
    assert sum(lb) / len(lb) < sum(la) / len(la), (la, lb)
