"""Wall-clock optimizations are bit-exact: sort plan, donation, flags.

The perf work (epoch sort plan, fused lexicographic sorts, buffer
donation, the Pallas segmented-scan routing) must change *nothing* about
virtual time — these tests pin every optimization against the seed path
over full engine runs, comparing whole state pytrees bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core.types import (
    CacheConfig,
    EngineConfig,
    FabricConfig,
    PlatformModel,
    QPConfig,
    SSDConfig,
    WorkloadConfig,
    integer_timestamps,
)
from repro.workloads import MultiTenant

SSD = SSDConfig()
PLAT = PlatformModel()
WL = WorkloadConfig(io_depth=16, read_frac=0.8)
SMALL = dict(num_sqs=8, sq_depth=64, fetch_width=16)


def _run(cfg, wl=WL, rounds=6):
    st = engine.init_state(cfg, SSD, wl)
    return engine.make_runner(cfg, SSD, wl, PLAT, rounds)(st)


def _assert_states_equal(a, b):
    for pa, pb in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        assert jnp.array_equal(pa[1], pb[1]), (
            f"leaf {jax.tree_util.keystr(pa[0])} diverged"
        )


CONFIGS = {
    # baseline datapath exercises the unit-rank path + map-lock scan
    "baseline_dp": EngineConfig(batched_datapath=False, **SMALL),
    # remote switched fabric + WFQ exercises the fused frame layout
    "remote_qos": EngineConfig(
        fabric=FabricConfig(
            remote=True,
            tx_bytes_per_us=10_000.0, rx_bytes_per_us=10_000.0,
            rtt_us=2.0, wire_txn_us=0.1, mtu_batch=4, mtu_timeout_us=5.0,
            switch_bytes_per_us=20_000.0, switch_fanin=4,
            qos_weights=(2.0, 1.0),
        ),
        **SMALL,
    ),
    # non-neutral QP exercises the fused CQ layout + doorbell scan
    "qp_coalesced": EngineConfig(
        qp=QPConfig(
            cq_coalesce_n=4, cq_coalesce_us=5.0, cq_doorbell_us=0.2,
            cq_poll_us=0.1, cqe_reap_us=0.05,
        ),
        **SMALL,
    ),
    # GPU page cache with hit-chasing exercises the partial-validity
    # epochs compaction must handle (hits never reach the rings, so
    # fetched batches are sparse in irregular patterns)
    "cached": EngineConfig(
        cache=CacheConfig(
            enabled=True, num_sets=8, ways=2, chase=2, readahead=1
        ),
        **SMALL,
    ),
}


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_sort_plan_bit_exact(name):
    """use_sort_plan=True reproduces the per-stage-sort path bit-exactly."""
    cfg = CONFIGS[name]
    wl = MultiTenant(io_depth=16) if name == "remote_qos" else WL
    a = _run(dataclasses.replace(cfg, use_sort_plan=False), wl)
    b = _run(dataclasses.replace(cfg, use_sort_plan=True), wl)
    _assert_states_equal(a, b)


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_compaction_bit_exact(name):
    """use_compaction=True reproduces the uncompacted path bit-exactly.

    The PR-8 epoch-compaction forms (dense round-robin timing layout,
    counting-sorted flash/lane contention, block CQ ranks, fused ring
    scatters) must be pure layout changes: whole-state pytree equality
    over full engine runs, per config family.
    """
    cfg = CONFIGS[name]
    wl = MultiTenant(io_depth=16) if name == "remote_qos" else WL
    a = _run(dataclasses.replace(cfg, use_compaction=False), wl)
    b = _run(dataclasses.replace(cfg, use_compaction=True), wl)
    _assert_states_equal(a, b)


def test_pallas_segscan_flag_gated_and_runs():
    """The Pallas routing defaults to auto (None) and runs when forced."""
    assert EngineConfig().use_pallas_segscan is None
    cfg = dataclasses.replace(
        CONFIGS["baseline_dp"], use_pallas_segscan=True
    )
    out = _run(cfg)
    assert float(out.metrics.completed) > 0


def test_pallas_segscan_auto_resolution():
    """``None`` resolves via the ``integer_timestamps`` static proof.

    The stock SSD's sched_us = 64/2.47e6*1e6 is fractional, so the auto
    default must fall back to the lax reference; an all-integer platform
    must resolve on; an explicit False always wins.
    """
    cfg = EngineConfig(batched_datapath=False, **SMALL)
    assert cfg.resolve_pallas_segscan(SSD, PLAT) is False

    int_ssd = SSD.replace(l_min_us=50.0, t_max_iops=64e6, n_instances=64)
    # Every checked cost integer; every byte-rate divides sqe_bytes (64)
    # and block_bytes (512) exactly.
    int_plat = PlatformModel(
        cpu_sqe_fetch_us=10.0, cpu_coal_byte_us=0.0, cpu_coal_base_us=1.0,
        dsa_sqe_fetch_us=4.0, dsa_coal_base_us=18.0,
        dsa_desc_issue_us=1.0, dsa_batch_setup_us=1.0,
        dsa_bytes_per_us=64.0, doorbell_poll_us=1.0,
        host_txn_base_us=1.0, host_bytes_per_us=64.0,
        txn_base_us=1.0, link_bytes_per_us=64.0,
        per_req_map_us=3.0, lock_per_req_us=1.0, lock_per_batch_us=1.0,
    )
    assert integer_timestamps(cfg, int_ssd, int_plat) is True
    assert cfg.resolve_pallas_segscan(int_ssd, int_plat) is True
    forced_off = dataclasses.replace(cfg, use_pallas_segscan=False)
    assert forced_off.resolve_pallas_segscan(int_ssd, int_plat) is False


def test_pallas_segscan_bit_exact_integer_times():
    """Pallas path ≡ lax path over a full run with integer-valued times.

    With platform/device parameters that keep every virtual timestamp an
    integer-valued f32 (< 2^24), the via-segmax reduction's cost-sum
    re-association cannot round differently, so the whole engine state
    must match bit-exactly.
    """
    # sched_us = n_instances / t_max_iops * 1e6 = 1.0 exactly.
    ssd = SSD.replace(l_min_us=50.0, t_max_iops=64e6, n_instances=64)
    plat = PlatformModel(
        cpu_sqe_fetch_us=10.0, cpu_coal_byte_us=0.0, cpu_coal_base_us=1.0,
        dsa_sqe_fetch_us=4.0, dsa_coal_base_us=18.0,
        host_txn_base_us=1.0, host_bytes_per_us=float(ssd.block_bytes),
        txn_base_us=1.0, link_bytes_per_us=float(ssd.block_bytes),
        per_req_map_us=3.0, lock_per_req_us=1.0, lock_per_batch_us=1.0,
    )
    cfg = EngineConfig(batched_datapath=False, **SMALL)
    wl = WorkloadConfig(io_depth=16, resubmit_delay_us=1.0)

    def run(use_pallas):
        c = dataclasses.replace(cfg, use_pallas_segscan=use_pallas)
        st = engine.init_state(c, ssd, wl)
        return engine.make_runner(c, ssd, wl, plat, 4)(st)

    _assert_states_equal(run(False), run(True))


def test_pallas_reap_bit_exact():
    """Fused post-and-reap kernel ≡ the scatter path over a full run.

    The kernel is integer bookkeeping + data movement only (no float
    arithmetic), so parity holds on any config with a neutral QP — the
    only path the kernel replaces.
    """
    cfg = CONFIGS["baseline_dp"]
    a = _run(cfg)
    b = _run(dataclasses.replace(cfg, use_pallas_reap=True))
    _assert_states_equal(a, b)


def test_pallas_flash_bit_exact_integer_times():
    """Fused die-contention kernel ≡ sort/scan path on integer times.

    The kernel's sequential per-chip fold re-associates the (max,+)
    recurrence relative to the reference's segmented scan, which is
    bit-exact exactly when timestamps stay integer-valued f32 — the same
    contract as ``use_pallas_segscan``.
    """
    # sched_us = 64 / 2.56e6 * 1e6 = 25.0 exactly; flash costs are
    # integers by default.
    ssd = SSD.replace(l_min_us=50.0, t_max_iops=2.56e6)
    cfg = CONFIGS["baseline_dp"]
    wl = WorkloadConfig(io_depth=16, read_frac=0.5, resubmit_delay_us=1.0)

    def run(use_pallas_flash):
        c = dataclasses.replace(cfg, use_pallas_flash=use_pallas_flash)
        st = engine.init_state(c, ssd, wl)
        return engine.make_runner(c, ssd, wl, PLAT, 6)(st)

    _assert_states_equal(run(False), run(True))


def test_donation_bit_exact():
    """donate=True reproduces the undonated runner bit-exactly."""
    cfg = CONFIGS["baseline_dp"]
    a = engine.init_state(cfg, SSD, WL)
    plain = engine.make_runner(cfg, SSD, WL, PLAT, 4, donate=False)
    a = plain(plain(a))
    b = engine.unalias(engine.init_state(cfg, SSD, WL))
    donated = engine.make_runner(cfg, SSD, WL, PLAT, 4, donate=True)
    b = donated(donated(b))
    _assert_states_equal(a, b)


def test_array_donation_bit_exact():
    """Array runner donation parity over a 2-drive vmapped array."""
    cfg = CONFIGS["baseline_dp"]
    a = engine.init_array_state(cfg, SSD, WL, 2)
    plain = engine.make_array_runner(cfg, SSD, WL, PLAT, 4, donate=False)
    a = plain(plain(a))
    b = engine.unalias(engine.init_array_state(cfg, SSD, WL, 2))
    donated = engine.make_array_runner(cfg, SSD, WL, PLAT, 4, donate=True)
    b = donated(donated(b))
    _assert_states_equal(a, b)
