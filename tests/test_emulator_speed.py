"""Wall-clock optimizations are bit-exact: sort plan, donation, flags.

The perf work (epoch sort plan, fused lexicographic sorts, buffer
donation, the Pallas segmented-scan routing) must change *nothing* about
virtual time — these tests pin every optimization against the seed path
over full engine runs, comparing whole state pytrees bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core.types import (
    EngineConfig,
    FabricConfig,
    PlatformModel,
    QPConfig,
    SSDConfig,
    WorkloadConfig,
)
from repro.workloads import MultiTenant

SSD = SSDConfig()
PLAT = PlatformModel()
WL = WorkloadConfig(io_depth=16, read_frac=0.8)
SMALL = dict(num_sqs=8, sq_depth=64, fetch_width=16)


def _run(cfg, wl=WL, rounds=6):
    st = engine.init_state(cfg, SSD, wl)
    return engine.make_runner(cfg, SSD, wl, PLAT, rounds)(st)


def _assert_states_equal(a, b):
    for pa, pb in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        assert jnp.array_equal(pa[1], pb[1]), (
            f"leaf {jax.tree_util.keystr(pa[0])} diverged"
        )


CONFIGS = {
    # baseline datapath exercises the unit-rank path + map-lock scan
    "baseline_dp": EngineConfig(batched_datapath=False, **SMALL),
    # remote switched fabric + WFQ exercises the fused frame layout
    "remote_qos": EngineConfig(
        fabric=FabricConfig(
            remote=True,
            tx_bytes_per_us=10_000.0, rx_bytes_per_us=10_000.0,
            rtt_us=2.0, wire_txn_us=0.1, mtu_batch=4, mtu_timeout_us=5.0,
            switch_bytes_per_us=20_000.0, switch_fanin=4,
            qos_weights=(2.0, 1.0),
        ),
        **SMALL,
    ),
    # non-neutral QP exercises the fused CQ layout + doorbell scan
    "qp_coalesced": EngineConfig(
        qp=QPConfig(
            cq_coalesce_n=4, cq_coalesce_us=5.0, cq_doorbell_us=0.2,
            cq_poll_us=0.1, cqe_reap_us=0.05,
        ),
        **SMALL,
    ),
}


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_sort_plan_bit_exact(name):
    """use_sort_plan=True reproduces the per-stage-sort path bit-exactly."""
    cfg = CONFIGS[name]
    wl = MultiTenant(io_depth=16) if name == "remote_qos" else WL
    a = _run(dataclasses.replace(cfg, use_sort_plan=False), wl)
    b = _run(dataclasses.replace(cfg, use_sort_plan=True), wl)
    _assert_states_equal(a, b)


def test_pallas_segscan_flag_gated_and_runs():
    """The Pallas routing is off by default and runs when enabled."""
    assert EngineConfig().use_pallas_segscan is False
    cfg = dataclasses.replace(
        CONFIGS["baseline_dp"], use_pallas_segscan=True
    )
    out = _run(cfg)
    assert float(out.metrics.completed) > 0


def test_pallas_segscan_bit_exact_integer_times():
    """Pallas path ≡ lax path over a full run with integer-valued times.

    With platform/device parameters that keep every virtual timestamp an
    integer-valued f32 (< 2^24), the via-segmax reduction's cost-sum
    re-association cannot round differently, so the whole engine state
    must match bit-exactly.
    """
    # sched_us = n_instances / t_max_iops * 1e6 = 1.0 exactly.
    ssd = SSD.replace(l_min_us=50.0, t_max_iops=64e6, n_instances=64)
    plat = PlatformModel(
        cpu_sqe_fetch_us=10.0, cpu_coal_byte_us=0.0, cpu_coal_base_us=1.0,
        dsa_sqe_fetch_us=4.0, dsa_coal_base_us=18.0,
        host_txn_base_us=1.0, host_bytes_per_us=float(ssd.block_bytes),
        txn_base_us=1.0, link_bytes_per_us=float(ssd.block_bytes),
        per_req_map_us=3.0, lock_per_req_us=1.0, lock_per_batch_us=1.0,
    )
    cfg = EngineConfig(batched_datapath=False, **SMALL)
    wl = WorkloadConfig(io_depth=16, resubmit_delay_us=1.0)

    def run(use_pallas):
        c = dataclasses.replace(cfg, use_pallas_segscan=use_pallas)
        st = engine.init_state(c, ssd, wl)
        return engine.make_runner(c, ssd, wl, plat, 4)(st)

    _assert_states_equal(run(False), run(True))


def test_donation_bit_exact():
    """donate=True reproduces the undonated runner bit-exactly."""
    cfg = CONFIGS["baseline_dp"]
    a = engine.init_state(cfg, SSD, WL)
    plain = engine.make_runner(cfg, SSD, WL, PLAT, 4, donate=False)
    a = plain(plain(a))
    b = engine.unalias(engine.init_state(cfg, SSD, WL))
    donated = engine.make_runner(cfg, SSD, WL, PLAT, 4, donate=True)
    b = donated(donated(b))
    _assert_states_equal(a, b)


def test_array_donation_bit_exact():
    """Array runner donation parity over a 2-drive vmapped array."""
    cfg = CONFIGS["baseline_dp"]
    a = engine.init_array_state(cfg, SSD, WL, 2)
    plain = engine.make_array_runner(cfg, SSD, WL, PLAT, 4, donate=False)
    a = plain(plain(a))
    b = engine.unalias(engine.init_array_state(cfg, SSD, WL, 2))
    donated = engine.make_array_runner(cfg, SSD, WL, PLAT, 4, donate=True)
    b = donated(donated(b))
    _assert_states_equal(a, b)
