"""Engine integration tests: conservation, fidelity, configuration matrix."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.types import EngineConfig, PlatformModel, SSDConfig, WorkloadConfig

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)


def small_cfg(**kw):
    base = dict(
        num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
        workers_per_unit=2, num_bufs=512, emulate_data=True,
    )
    base.update(kw)
    return EngineConfig(**base)


def test_request_conservation():
    """Closed loop: fetched == completed, outstanding == Q*io_depth."""
    cfg = small_cfg()
    wl = WorkloadConfig(io_depth=16)
    st = engine.simulate(cfg, SSD, wl, rounds=32)
    m = st.metrics
    assert float(m.completed) == float(m.fetched)
    assert float(m.completed) > 0
    outstanding = np.asarray(st.rings.tail - st.rings.head)
    assert outstanding.sum() + 0 == cfg.num_sqs * wl.io_depth  # all resubmitted


def test_virtual_iops_matches_target_under_load():
    """With deep queues the emulated device sustains ~T_max (paper Fig. 10)."""
    cfg = small_cfg(num_sqs=16, fetch_width=64)
    wl = WorkloadConfig(io_depth=128)
    st = engine.simulate(cfg, SSD, wl, rounds=96)
    iops = float(st.metrics.iops())
    assert iops == pytest.approx(2.47e6, rel=0.15)


def test_low_load_latency_floor():
    """Single outstanding request per SQ ⇒ E2E ≈ L_min + small overheads."""
    cfg = small_cfg(num_sqs=4, num_units=4)
    wl = WorkloadConfig(io_depth=1, resubmit_delay_us=5.0)
    st = engine.simulate(cfg, SSD, wl, rounds=64)
    e2e = float(st.metrics.avg_e2e_us())
    assert 50.0 <= e2e <= 80.0  # floor + fetch/copy overheads, no queueing


def test_functional_reads_land_in_buffers():
    cfg = small_cfg()
    wl = WorkloadConfig(io_depth=8)
    st = engine.simulate(cfg, SSD, wl, rounds=8)
    bufs = np.asarray(st.bufs)
    assert np.isfinite(bufs).all()
    assert (np.abs(bufs).sum(axis=1) > 0).any()  # some reads materialized


@pytest.mark.parametrize("frontend", ["centralized", "distributed"])
@pytest.mark.parametrize("mode", ["per_request", "aggregated"])
@pytest.mark.parametrize("batched", [False, True])
def test_config_matrix_runs(frontend, mode, batched):
    cfg = small_cfg(
        frontend=frontend, mode=mode, batched_datapath=batched,
        num_sqs=4, fetch_width=8, num_units=2 if frontend == "distributed" else 1,
    )
    wl = WorkloadConfig(io_depth=8)
    st = engine.simulate(cfg, SSD, wl, rounds=8)
    m = st.metrics
    assert float(m.completed) > 0
    assert np.isfinite(float(m.avg_e2e_us()))
    assert float(m.avg_e2e_us()) >= 50.0 - 1e-3  # never beats the device floor


def test_swarmio_beats_baseline_iops():
    """The full SwarmIO config sustains more virtual IOPS than the NVMeVirt
    baseline config under identical GPU-initiated-style load (many SQs)."""
    fast = SSDConfig(t_max_iops=4e7, l_min_us=30.0, n_instances=256,
                     num_blocks=1 << 12)
    wl = WorkloadConfig(io_depth=64)
    base_cfg = small_cfg(
        num_sqs=32, fetch_width=64, frontend="centralized",
        mode="per_request", batched_datapath=False, coalesced=False,
        num_units=1, workers_per_unit=8, emulate_data=False,
    )
    swarm_cfg = small_cfg(
        num_sqs=32, fetch_width=64, frontend="distributed",
        mode="aggregated", batched_datapath=True, coalesced=True,
        num_units=8, emulate_data=False,
    )
    base = engine.simulate(base_cfg, fast, wl, rounds=24)
    swarm = engine.simulate(swarm_cfg, fast, wl, rounds=24)
    b, s = float(base.metrics.iops()), float(swarm.metrics.iops())
    assert s > 3 * b, (b, s)


def test_timing_scope_local_vs_global_skew():
    """Skewed load (one hot SQ): global timing model sustains target, local
    models cap at 1/U of it (the paper's motivation for the global model)."""
    fast = SSDConfig(t_max_iops=1e7, l_min_us=30.0, n_instances=64,
                     num_blocks=1 << 12)
    # All load on SQ 0 (unit 0); other SQs idle.
    cfg_g = small_cfg(num_sqs=8, num_units=8, fetch_width=64,
                      timing_scope="global", emulate_data=False)
    cfg_l = cfg_g.replace(timing_scope="local")
    wl = WorkloadConfig(io_depth=1)

    def skewed_sim(cfg):
        st = engine.init_state(cfg, fast, WorkloadConfig(io_depth=256))
        # Zero out all SQs but 0 by pushing their submit times to infinity.
        far = jnp.full_like(st.rings.submit_time[1:], 3e38)
        st = dataclasses.replace(
            st,
            rings=dataclasses.replace(
                st.rings,
                submit_time=st.rings.submit_time.at[1:].set(far),
                tail=st.rings.tail.at[1:].set(st.rings.head[1:]),
            ),
        )
        return engine.make_runner(cfg, fast, wl, PlatformModel(), 48)(st)

    g = skewed_sim(cfg_g)
    l = skewed_sim(cfg_l)
    gi, li = float(g.metrics.iops()), float(l.metrics.iops())
    assert gi > 2 * li, (gi, li)
