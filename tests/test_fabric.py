"""Fabric/interconnect layer tests (remote all-flash arrays).

Contracts under test:
  * the neutral default (``remote=False``) and a zero-cost remote wire
    are *exact* no-ops — engine and client completion times reproduce
    the fabric-less pipeline bit-exactly (the acceptance parity bar);
  * fabric serialization is monotone: lower link bandwidth (or added
    RTT) never decreases any completion time;
  * MTU batching holds early frames for the flush and the timeout
    bounds the wait;
  * replica reads route around a placement-skewed batch via the
    least-loaded link;
  * ``make_sharded_array_runner`` (shard_map) matches the vmap array
    runner bit-exactly on a 1-device mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.client import StorageClient
from repro.core.fabric import fabric_hop
from repro.core.types import (
    EngineConfig,
    FabricConfig,
    PlatformModel,
    SSDConfig,
    WorkloadConfig,
)

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)

ZERO_COST = FabricConfig(remote=True)  # remote, but a free wire


def _flash_store(words=8):
    return jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, words)
    )


# ---------------------------------------------------------------------------
# Unit-level hop behavior.
# ---------------------------------------------------------------------------

def test_zero_cost_hop_is_identity():
    """Unconstrained bandwidth, zero RTT/txn, no batching: frames land
    at their ready times and the link cursor never moves — across
    multiple epochs (later epochs may carry earlier-timed frames)."""
    busy = jnp.float32(0)
    for t0 in (100.0, 10.0):  # second epoch is *earlier* than the first
        t = t0 + jnp.arange(16, dtype=jnp.float32)
        nbytes = jnp.full((16,), 576.0)
        busy, out = fabric_hop(
            busy, t, nbytes, jnp.ones((16,), bool), ZERO_COST, float("inf")
        )
        np.testing.assert_array_equal(np.asarray(out), np.asarray(t))
        assert float(jnp.max(busy)) == 0.0


def test_finite_bandwidth_serializes():
    """N frames of B bytes on one link: the last lands no earlier than
    first_ready + N*B/bw, and the cursor advances accordingly."""
    n, b, bw = 32, 528.0, 1000.0
    fab = FabricConfig(remote=True, tx_bytes_per_us=bw, rx_bytes_per_us=bw)
    t = jnp.zeros((n,), jnp.float32)
    busy, out = fabric_hop(
        jnp.float32(0), t, jnp.full((n,), b), jnp.ones((n,), bool), fab, bw
    )
    assert float(jnp.max(out)) == pytest.approx(n * b / bw, rel=1e-5)
    assert float(jnp.max(busy)) == pytest.approx(n * b / bw, rel=1e-5)
    # Streaming: frame k lands after (k+1) frames' bytes, not all at once.
    np.testing.assert_allclose(
        np.sort(np.asarray(out)),
        (np.arange(n) + 1) * b / bw,
        rtol=1e-5,
    )


def test_mtu_batching_waits_for_flush_and_timeout_bounds_it():
    n = 16
    t = jnp.arange(n, dtype=jnp.float32)  # 1 us apart
    ones = jnp.ones((n,), bool)
    nbytes = jnp.full((n,), 64.0)
    fab = FabricConfig(remote=True, mtu_batch=4, mtu_timeout_us=1e6)
    _, out = fabric_hop(jnp.float32(0), t, nbytes, ones, fab, float("inf"))
    r = np.asarray(out).reshape(4, 4)
    # Every member of an MTU batch waits for the batch's last frame.
    np.testing.assert_allclose(r, r[:, -1:].repeat(4, axis=1), rtol=1e-6)
    # A tight timeout caps the wait.
    fab_t = FabricConfig(remote=True, mtu_batch=4, mtu_timeout_us=1.5)
    _, out_t = fabric_hop(jnp.float32(0), t, nbytes, ones, fab_t,
                          float("inf"))
    assert (np.asarray(out_t) <= np.asarray(t) + 1.5 + 1e-5).all()


def test_invalid_rows_pass_through_untouched():
    n = 12
    t = jnp.arange(n, dtype=jnp.float32)
    valid = (jnp.arange(n) % 2 == 0)
    fab = FabricConfig(remote=True, rtt_us=8.0, tx_bytes_per_us=100.0,
                       rx_bytes_per_us=100.0)
    _, out = fabric_hop(
        jnp.float32(0), t, jnp.full((n,), 64.0), valid, fab, 100.0
    )
    np.testing.assert_array_equal(
        np.asarray(out)[1::2], np.asarray(t)[1::2]
    )
    assert (np.asarray(out)[::2] > np.asarray(t)[::2]).all()


def test_fabric_config_validation_and_neutrality():
    with pytest.raises(ValueError, match="mtu_batch"):
        FabricConfig(mtu_batch=0)
    with pytest.raises(ValueError, match="bytes_per_us"):
        FabricConfig(rx_bytes_per_us=0.0)
    with pytest.raises(ValueError, match="rtt_us"):
        FabricConfig(rtt_us=-1.0)
    assert FabricConfig().neutral
    assert ZERO_COST.neutral
    assert FabricConfig(remote=True, mtu_batch=8).neutral  # timeout 0
    assert not FabricConfig(remote=True, rtt_us=1.0).neutral
    assert not FabricConfig(remote=True, rx_bytes_per_us=1e4).neutral


# ---------------------------------------------------------------------------
# Parity: local drive == remote drive behind a zero-cost wire, bit-exact.
# ---------------------------------------------------------------------------

def test_engine_parity_zero_cost_wire_bit_exact():
    """The fabric stage on a free wire reproduces the local pipeline
    bit-exactly over many engine rounds — metrics and device state."""
    wl = WorkloadConfig(io_depth=32)
    local = engine.simulate(CFG, SSD, wl, rounds=24)
    remote = engine.simulate(
        CFG.replace(fabric=ZERO_COST), SSD, wl, rounds=24
    )
    for got, want in [
        (remote.metrics.sum_e2e, local.metrics.sum_e2e),
        (remote.metrics.lat_hist, local.metrics.lat_hist),
        (remote.metrics.last_completion, local.metrics.last_completion),
        (remote.device.tstate.busy_until, local.device.tstate.busy_until),
        (remote.device.dsa_time, local.device.dsa_time),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # The free wire really occupied no link time.
    assert float(jnp.max(remote.device.fabric.tx_busy)) == 0.0
    assert float(jnp.max(remote.device.fabric.rx_busy)) == 0.0


def test_client_parity_zero_cost_wire_bit_exact():
    flash = _flash_store()
    lba = (jnp.arange(512, dtype=jnp.int32) * 37) % SSD.num_blocks
    cfg = EngineConfig(num_units=4, fetch_width=64)
    local = StorageClient(SSD, cfg)
    remote = StorageClient(SSD, cfg.replace(fabric=ZERO_COST))
    _, _, dl = local.read(local.init_state(), flash, lba, jnp.float32(3.0))
    _, _, dr = remote.read(remote.init_state(), flash, lba, jnp.float32(3.0))
    np.testing.assert_array_equal(np.asarray(dl), np.asarray(dr))


def test_engine_parity_mixed_writes_zero_cost_wire():
    """Parity holds through the flash backend too (writes change the TX
    payload bytes, but a free wire still prices them at zero)."""
    from repro import workloads

    wl = workloads.MixedReadWrite(io_depth=16, read_frac=0.7)
    local = engine.simulate(CFG, SSD, wl, rounds=16)
    remote = engine.simulate(
        CFG.replace(fabric=ZERO_COST), SSD, wl, rounds=16
    )
    np.testing.assert_array_equal(
        np.asarray(local.metrics.lat_hist),
        np.asarray(remote.metrics.lat_hist),
    )


# ---------------------------------------------------------------------------
# Monotonicity: a worse wire never helps.
# ---------------------------------------------------------------------------

def test_lower_bandwidth_never_decreases_any_completion():
    flash = _flash_store()
    lba = (jnp.arange(384, dtype=jnp.int32) * 29) % SSD.num_blocks
    cfg = EngineConfig(num_units=4, fetch_width=64)
    prev = None
    for bw in [float("inf"), 8000.0, 2000.0, 500.0]:
        fab = FabricConfig(remote=True, rtt_us=4.0, tx_bytes_per_us=bw,
                           rx_bytes_per_us=bw, wire_txn_us=0.2,
                           mtu_batch=8, mtu_timeout_us=20.0)
        client = StorageClient(SSD, cfg.replace(fabric=fab))
        _, _, done = client.read(
            client.init_state(), flash, lba, jnp.float32(0)
        )
        done = np.asarray(done)
        if prev is not None:
            assert (done >= prev - 1e-5).all(), bw
        prev = done


def test_rtt_adds_full_round_trip_to_an_idle_read():
    flash = _flash_store()
    lba = jnp.arange(8, dtype=jnp.int32)
    cfg = EngineConfig(num_units=4, fetch_width=64)
    base = StorageClient(SSD, cfg.replace(fabric=ZERO_COST))
    lag = StorageClient(
        SSD, cfg.replace(fabric=FabricConfig(remote=True, rtt_us=30.0))
    )
    _, _, d0 = base.read(base.init_state(), flash, lba, jnp.float32(0))
    _, _, d1 = lag.read(lag.init_state(), flash, lba, jnp.float32(0))
    np.testing.assert_allclose(
        np.asarray(d1 - d0), 30.0, rtol=1e-5
    )


def test_engine_fabric_limited_regime_is_monotone():
    """Engine closed loop: sustained IOPS never increases as the link
    narrows, and a hard-clamped link lands near its frame roof."""
    wl = WorkloadConfig(io_depth=256)
    ssd = SSDConfig(t_max_iops=1e7, l_min_us=30.0, n_instances=256,
                    num_blocks=1 << 12)
    iops = []
    for bw in [float("inf"), 4000.0, 1000.0]:
        fab = FabricConfig(remote=True, tx_bytes_per_us=bw,
                           rx_bytes_per_us=bw)
        out = engine.simulate(
            CFG.replace(fabric=fab), ssd, wl, rounds=24
        )
        iops.append(float(out.metrics.iops()))
    assert iops[0] >= iops[1] >= iops[2]
    frame = FabricConfig().cqe_bytes + ssd.block_bytes
    roof = 1000.0 / frame * 1e6
    assert iops[2] == pytest.approx(roof, rel=0.25)


# ---------------------------------------------------------------------------
# Replicated reads over remote links.
# ---------------------------------------------------------------------------

def test_replica_read_spreads_skewed_batch_over_links():
    """All blocks homed on drive 0: replicas=1 serializes on one link,
    replicas=M re-engages the others and cuts the makespan."""
    m, n = 4, 256
    fab = FabricConfig(remote=True, rtt_us=5.0, tx_bytes_per_us=8000.0,
                       rx_bytes_per_us=2000.0)
    client = StorageClient(
        SSD, EngineConfig(num_units=4, fetch_width=64,
                          fabric=fab)
    )
    flash = _flash_store()
    skew = ((jnp.arange(n, dtype=jnp.int32) * 13) % SSD.num_blocks) \
        // m * m  # every lba % m == 0
    state = client.init_array_state(m)
    _, _, d1 = client.read_replicated(
        state, flash, skew, jnp.float32(0), replicas=1
    )
    _, _, dm = client.read_replicated(
        state, flash, skew, jnp.float32(0), replicas=m
    )
    assert float(jnp.max(dm)) < 0.6 * float(jnp.max(d1))


def test_replica_read_matches_striped_for_uniform_single_replica():
    """replicas=1 routes every block to its home drive (lba % M) — the
    same placement as an lba-keyed stripe; completions stay a
    permutation-free match on a round-robin-homed batch."""
    m, n = 4, 512
    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    flash = _flash_store()
    # lba ≡ i (mod m): home drive of request i == i % m, so replicas=1
    # placement coincides with read_striped's fixed interleave.
    lba = (jnp.arange(n, dtype=jnp.int32) * (m + 1)) % SSD.num_blocks
    state = client.init_array_state(m)
    _, _, ds = client.read_striped(state, flash, lba, jnp.float32(0))
    _, _, dr = client.read_replicated(
        state, flash, lba, jnp.float32(0), replicas=1
    )
    np.testing.assert_array_equal(np.asarray(ds), np.asarray(dr))


def test_replicas_validation():
    client = StorageClient(SSD, EngineConfig(num_units=4, fetch_width=64))
    state = client.init_array_state(2)
    with pytest.raises(ValueError, match="replicas"):
        client.read_replicated(
            state, _flash_store(), jnp.arange(8, dtype=jnp.int32),
            jnp.float32(0), replicas=3,
        )


# ---------------------------------------------------------------------------
# shard_map array runner.
# ---------------------------------------------------------------------------

def test_sharded_array_runner_matches_vmap_on_single_device_mesh():
    wl = WorkloadConfig(io_depth=16)
    plat = PlatformModel()
    states = engine.init_array_state(CFG, SSD, wl, 4)
    vm = engine.make_array_runner(CFG, SSD, wl, plat, 12)(states)
    sh = engine.make_sharded_array_runner(CFG, SSD, wl, plat, 12)(states)
    for a, b in zip(jax.tree.leaves(vm), jax.tree.leaves(sh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 JAX devices (e.g. XLA_FLAGS="
           "--xla_force_host_platform_device_count=2)",
)
def test_sharded_array_runner_multi_device():
    wl = WorkloadConfig(io_depth=16)
    plat = PlatformModel()
    states = engine.init_array_state(CFG, SSD, wl, 4)
    vm = engine.make_array_runner(CFG, SSD, wl, plat, 8)(states)
    sh = engine.make_sharded_array_runner(CFG, SSD, wl, plat, 8)(states)
    np.testing.assert_allclose(
        np.asarray(vm.metrics.completed), np.asarray(sh.metrics.completed)
    )


# ---------------------------------------------------------------------------
# Remote arrays end to end.
# ---------------------------------------------------------------------------

def test_remote_array_vmaps_per_drive_links():
    """An M-drive remote array carries one pair of link cursors per
    drive, and a constrained link shows up in every drive's cursor."""
    fab = FabricConfig(remote=True, rx_bytes_per_us=1000.0,
                       tx_bytes_per_us=8000.0)
    arr = engine.simulate(
        CFG.replace(fabric=fab), SSD, WorkloadConfig(io_depth=32),
        rounds=12, num_devices=3,
    )
    # (M, T) stacked cursors: one per-tenant vector per drive (T=1 here).
    rx = np.asarray(arr.device.fabric.rx_busy)
    assert rx.shape == (3, 1)
    assert (rx > 0.0).all()


def test_fabric_composes_with_non_neutral_qp():
    """RX hop then CQ coalescing: reaped >= wire-delayed done and the
    run still completes (the two layers stack without conflict)."""
    from repro.core.types import QPConfig

    fab = FabricConfig(remote=True, rtt_us=5.0, rx_bytes_per_us=2000.0,
                       tx_bytes_per_us=8000.0)
    qp = QPConfig(cq_coalesce_n=4, cq_coalesce_us=40.0, cq_doorbell_us=0.5)
    out = engine.simulate(
        CFG.replace(fabric=fab, qp=qp), SSD,
        WorkloadConfig(io_depth=32), rounds=16,
    )
    assert float(out.metrics.completed) > 0
    assert np.isfinite(float(out.metrics.avg_e2e_us()))
