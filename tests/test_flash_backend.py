"""Flash-backend (pipeline stage 4) tests.

The stage's contract: with ``mapping_hit_rate=1.0``, no writes, and GC
idle it is an exact no-op (PR-1 read latencies reproduce bit-exactly);
with writes/misses/GC it only ever adds time, and die cursors never move
backwards.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.client import ClientState, StorageClient
from repro.core.flash import FlashState, chip_of, flash_stage
from repro.core.types import (
    OP_WRITE,
    EngineConfig,
    PlatformModel,
    SSDConfig,
    WorkloadConfig,
)
from repro import workloads

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)


def _flash_store(n_blocks=None, words=8):
    n = n_blocks or SSD.num_blocks
    return jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, words))


# ---------------------------------------------------------------------------
# Parity: the 4-stage pipeline reproduces PR-1 completions bit-exactly.
# ---------------------------------------------------------------------------

def test_engine_parity_read_only_bit_exact():
    """flash_backend on vs off: identical virtual-time results for a
    read-only workload at mapping_hit_rate=1.0 (GC never wakes)."""
    wl = WorkloadConfig(io_depth=32)
    on = engine.simulate(CFG, SSD, wl, rounds=24)
    off = engine.simulate(CFG, SSD.replace(flash_backend=False), wl,
                          rounds=24)
    for got, want in [
        (on.metrics.sum_e2e, off.metrics.sum_e2e),
        (on.metrics.lat_hist, off.metrics.lat_hist),
        (on.metrics.last_completion, off.metrics.last_completion),
        (on.device.tstate.busy_until, off.device.tstate.busy_until),
        (on.device.dsa_time, off.device.dsa_time),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # The stage really was a no-op: no die ever became busy, no GC ran.
    assert float(jnp.max(on.device.flash.chip_busy)) == 0.0
    assert float(on.device.flash.gc_count) == 0.0


def test_client_parity_read_only_bit_exact():
    """StorageClient reads at hit rate 1.0 are bit-identical with the
    flash backend enabled and disabled."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    flash = _flash_store()
    lba = (jnp.arange(512, dtype=jnp.int32) * 37) % SSD.num_blocks
    on = StorageClient(SSD, cfg)
    off = StorageClient(SSD.replace(flash_backend=False), cfg)
    _, _, done_on = on.read(on.init_state(), flash, lba, jnp.float32(3.0))
    _, _, done_off = off.read(off.init_state(), flash, lba, jnp.float32(3.0))
    np.testing.assert_array_equal(np.asarray(done_on), np.asarray(done_off))


def test_preconditioned_read_only_still_parity():
    """A steady-state drive without writes never GCs: free pool (the
    over-provisioned spare area) sits above the watermark."""
    ssd = SSD.replace(preconditioned=True)
    wl = WorkloadConfig(io_depth=32)
    on = engine.simulate(CFG, ssd, wl, rounds=16)
    off = engine.simulate(CFG, SSD.replace(flash_backend=False), wl,
                          rounds=16)
    np.testing.assert_array_equal(
        np.asarray(on.metrics.lat_hist), np.asarray(off.metrics.lat_hist)
    )
    assert float(on.device.flash.gc_count) == 0.0


# ---------------------------------------------------------------------------
# Mapping (CMT) misses.
# ---------------------------------------------------------------------------

def test_mapping_miss_adds_translation_read():
    """hit_rate=0: every read pays at least one extra flash_read_us."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    flash = _flash_store()
    lba = (jnp.arange(256, dtype=jnp.int32) * 13) % SSD.num_blocks
    hit = StorageClient(SSD, cfg)
    mis = StorageClient(SSD.replace(mapping_hit_rate=0.0), cfg)
    _, _, dh = hit.read(hit.init_state(), flash, lba, jnp.float32(0))
    _, _, dm = mis.read(mis.init_state(), flash, lba, jnp.float32(0))
    assert float(jnp.min(dm - dh)) >= SSD.flash_read_us - 1e-3


def test_mapping_miss_rate_tracks_config():
    """The deterministic miss hash approximates the configured rate and
    differs across epochs (io_seq-salted)."""
    from repro.core.device import make_direct_batch
    from repro.core.flash import mapping_miss

    ssd = SSD.replace(mapping_hit_rate=0.7)
    n = 4096
    batch = make_direct_batch(jnp.zeros((n,), jnp.int32), jnp.float32(0))

    st0 = FlashState.init(ssd)
    st1 = dataclasses.replace(st0, io_seq=jnp.int32(7919))
    m0 = mapping_miss(st0, batch, ssd)
    m1 = mapping_miss(st1, batch, ssd)
    assert float(jnp.mean(m0.astype(jnp.float32))) == pytest.approx(
        0.3, abs=0.03
    )
    assert bool(jnp.any(m0 != m1))
    # Address-salted: identical req_id streams over different LBAs (two
    # array drives with salted workloads) produce different miss sets.
    other = dataclasses.replace(
        batch, lba=jnp.full((n,), 17, jnp.int32)
    )
    m2 = mapping_miss(st0, other, ssd)
    assert bool(jnp.any(m0 != m2))


# ---------------------------------------------------------------------------
# Writes + GC.
# ---------------------------------------------------------------------------

def test_writes_pay_program_latency_and_serialize():
    """Every write takes >= program_us; sustained writes queue at the
    die-array program ceiling, not the timing-model read ceiling."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    flash = _flash_store()
    n = 512
    lba = (jnp.arange(n, dtype=jnp.int32) * 29) % SSD.num_blocks
    data = jnp.ones((n, 8), jnp.float32)
    st, flash2, done = client.write(
        client.init_state(), flash, data, lba, jnp.float32(0)
    )
    lat = np.asarray(done)
    assert (lat >= SSD.flash_program_us - 1e-3).all()
    # Log-structured round-robin placement: the batch spreads evenly, so
    # the makespan is ~n/num_chips programs deep, far below one die's
    # serial time.
    per_chip = n / SSD.num_chips
    assert float(done.max()) >= per_chip * SSD.flash_program_us - 1e-3
    assert float(done.max()) < 2.5 * per_chip * SSD.flash_program_us
    # Functional write landed.
    np.testing.assert_array_equal(np.asarray(flash2[lba]), np.asarray(data))


def test_gc_never_schedules_chips_backwards():
    """Across many engine rounds of a steady-state mixed workload, die
    cursors are monotonically non-decreasing and GC only accumulates."""
    ssd = SSD.replace(num_blocks=1 << 12)
    wl = workloads.SteadyStateMixed(io_depth=32, read_frac=0.5, theta=0.9)
    plat = PlatformModel()
    st = engine.init_state(CFG, ssd, wl)
    chips = np.asarray(st.device.flash.chip_busy)
    gc = 0.0
    free_min = float(st.device.flash.free_pages)
    for _ in range(20):
        st = engine.engine_round(st, CFG, ssd, wl, plat)
        new_chips = np.asarray(st.device.flash.chip_busy)
        assert (new_chips >= chips - 1e-6).all()
        new_gc = float(st.device.flash.gc_count)
        assert new_gc >= gc
        chips, gc = new_chips, new_gc
        free_min = min(free_min, float(st.device.flash.free_pages))
    assert gc > 0.0, "steady-state mixed load must trigger GC"
    # GC kept the pool from collapsing to zero.
    assert float(st.device.flash.free_pages) > 0.0


def test_steady_state_inflates_tail_vs_fresh():
    """Same 70/30 mix: the preconditioned drive GCs and its p99 blows up
    relative to the fresh drive (fig20's contrast)."""
    ssd = SSD.replace(num_blocks=1 << 12)
    cfg = CFG.replace(poll_quantum_us=50.0)
    fresh = engine.simulate(
        cfg, ssd, workloads.MixedReadWrite(io_depth=32, read_frac=0.7),
        rounds=48,
    )
    steady = engine.simulate(
        cfg, ssd, workloads.SteadyStateMixed(io_depth=32, read_frac=0.7),
        rounds=48,
    )
    assert float(steady.device.flash.gc_count) > float(
        fresh.device.flash.gc_count
    )
    assert float(steady.metrics.p99_us()) > float(fresh.metrics.p99_us())


# ---------------------------------------------------------------------------
# Array (vmap) invariants.
# ---------------------------------------------------------------------------

def test_write_array_matches_per_device_loop():
    """write_array's vmapped pricing equals M independent single-device
    writes, bit-exactly."""
    cfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, cfg)
    flash = _flash_store()
    m, n = 4, 128
    lba = jnp.stack(
        [(jnp.arange(n, dtype=jnp.int32) * (3 + i)) % SSD.num_blocks
         for i in range(m)]
    )
    data = jnp.ones((m, n, 8), jnp.float32) * 5.0
    astate = client.init_array_state(m)
    astate2, _, adone = client.write_array(
        astate, flash, data, lba, jnp.float32(0)
    )
    for i in range(m):
        sti = ClientState(dev=jax.tree.map(lambda x: x[i], astate.dev))
        sti2, _, di = client.write(
            sti, flash, data[i], lba[i], jnp.float32(0)
        )
        np.testing.assert_array_equal(np.asarray(adone[i]), np.asarray(di))
        np.testing.assert_array_equal(
            np.asarray(jax.tree.map(lambda x: x[i], astate2.dev.flash)
                       .chip_busy),
            np.asarray(sti2.dev.flash.chip_busy),
        )


def test_multi_device_array_with_writes():
    """The vmapped M-drive array carries independent per-drive flash
    state (leading device axis on every FlashState leaf)."""
    wl = workloads.MixedReadWrite(io_depth=16, read_frac=0.7)
    arr = engine.simulate(CFG, SSD, wl, rounds=16, num_devices=3)
    assert arr.device.flash.chip_busy.shape == (3, SSD.num_chips)
    assert arr.device.flash.free_pages.shape == (3,)
    # Per-drive streams are salted: die usage diverges across drives.
    chips = np.asarray(arr.device.flash.chip_busy)
    assert not np.array_equal(chips[0], chips[1])
    assert float(engine.aggregate_iops(arr)) > 0.0


# ---------------------------------------------------------------------------
# Unit-level stage behavior + config validation.
# ---------------------------------------------------------------------------

def test_flash_stage_writes_advance_only_their_dies():
    """Direct stage call: a write-only batch advances exactly the dies
    the round-robin allocator placed programs on."""
    ssd = SSD
    n = ssd.num_chips // 2  # fewer writes than dies
    from repro.core.device import make_direct_batch

    batch = make_direct_batch(
        jnp.arange(n, dtype=jnp.int32), jnp.float32(0),
        opcode=jnp.full((n,), OP_WRITE, jnp.int32),
    )
    st = FlashState.init(ssd)
    arrival = jnp.zeros((n,), jnp.float32)
    target = jnp.full((n,), ssd.l_min_us, jnp.float32)
    st2, flash_done = flash_stage(st, batch, arrival, target, ssd)
    busy = np.asarray(st2.chip_busy)
    assert (busy[:n] == ssd.flash_program_us).all()
    assert (busy[n:] == 0.0).all()
    np.testing.assert_allclose(
        np.asarray(flash_done), ssd.flash_program_us, rtol=1e-6
    )


def test_chip_of_spreads_addresses():
    lba = jnp.arange(10_000, dtype=jnp.int32)
    counts = np.bincount(np.asarray(chip_of(lba, SSD)),
                         minlength=SSD.num_chips)
    assert counts.min() > 0.5 * counts.mean()


def test_ssd_config_validation():
    with pytest.raises(ValueError, match="mapping_hit_rate"):
        SSDConfig(mapping_hit_rate=1.5)
    with pytest.raises(ValueError, match="num_channels"):
        SSDConfig(num_channels=0)
    with pytest.raises(ValueError, match="over_provision"):
        SSDConfig(over_provision=0.0)
    with pytest.raises(ValueError, match="gc_watermark"):
        SSDConfig(over_provision=0.05, gc_watermark=0.05)
