"""Custom-VJP flash attention: forward AND gradients vs naive autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.flash_vjp import flash_attention_jnp

CASES = [
    # (B, Hq, Hkv, S, D, causal, window, cap, qc, kc)
    (1, 2, 2, 64, 16, True, None, None, 16, 16),
    (2, 4, 2, 64, 16, True, None, None, 32, 16),
    (1, 4, 1, 128, 8, True, 32, None, 32, 32),
    (1, 2, 2, 64, 16, True, None, 30.0, 16, 32),
    (1, 4, 2, 128, 16, True, 64, 50.0, 64, 32),
    (1, 2, 2, 64, 16, False, None, None, 64, 64),
]


def naive(q, k, v, causal, window, cap):
    return ref.attention_ref(
        q, k, v, causal=causal, window=window, logit_softcap=cap
    )


@pytest.mark.parametrize("case", CASES)
def test_forward_and_grads(case):
    b, hq, hkv, s, d, causal, window, cap, qc, kc = case
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    ct = jax.random.normal(ks[3], (b, hq, s, d))
    scale = d ** -0.5

    out = flash_attention_jnp(q, k, v, causal, window, cap, scale, qc, kc)
    expect = naive(q, k, v, causal, window, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)

    def f_flash(q, k, v):
        o = flash_attention_jnp(q, k, v, causal, window, cap, scale, qc, kc)
        return jnp.sum(o * ct)

    def f_naive(q, k, v):
        return jnp.sum(naive(q, k, v, causal, window, cap) * ct)

    g_flash = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g_naive = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for gf, gn, name in zip(g_flash, g_naive, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gf), np.asarray(gn), rtol=5e-3, atol=5e-4,
            err_msg=f"d{name} mismatch",
        )
