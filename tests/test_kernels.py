"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes/dtypes.

All kernels run in interpret mode on CPU (the kernel body itself executes,
BlockSpec pipeline included); on TPU the same entry points compile natively.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.block_gather import block_gather, block_gather_tiled
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.seg_scan import seg_scan


# ---------------------------------------------------------------------------
# block_gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nblocks,width,n", [
    (64, 128, 32), (256, 128, 256), (128, 256, 64), (32, 512, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gather(nblocks, width, n, dtype):
    key = jax.random.PRNGKey(0)
    flash = jax.random.normal(key, (nblocks, width), dtype=dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, nblocks)
    out = block_gather(flash, idx, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.block_gather_ref(flash, idx))
    )


@pytest.mark.parametrize("tile", [4, 8])
def test_block_gather_tiled(tile):
    flash = jax.random.normal(jax.random.PRNGKey(2), (64, 128))
    idx = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 64)
    out = block_gather_tiled(flash, idx, tile=tile, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.block_gather_ref(flash, idx))
    )


# ---------------------------------------------------------------------------
# seg_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunk", [(16, 8), (256, 64), (100, 32), (1, 8)])
def test_seg_scan(n, chunk):
    rng = np.random.default_rng(n)
    vals = rng.uniform(-100, 100, n).astype(np.float32)
    heads = rng.random(n) < 0.2
    heads[0] = True
    out = seg_scan(jnp.asarray(vals), jnp.asarray(heads), chunk=chunk,
                   interpret=True)
    expect = ref.seg_scan_ref(jnp.asarray(vals), jnp.asarray(heads))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Hq, Hkv, S, D, causal, window, softcap)
    (1, 4, 4, 128, 64, True, None, None),        # MHA causal
    (2, 8, 2, 128, 64, True, None, None),        # GQA 4:1
    (1, 4, 1, 256, 32, True, None, None),        # MQA
    (1, 4, 4, 256, 64, True, 64, None),          # local window
    (1, 4, 2, 128, 64, True, None, 50.0),        # logit softcap (gemma2)
    (1, 8, 2, 256, 64, True, 128, 30.0),         # local + softcap
    (1, 2, 2, 128, 128, False, None, None),      # bidirectional
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    b, hq, hkv, s, d, causal, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype=dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype=dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype=dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=cap,
        block_q=64, block_k=64, interpret=True,
    )
    expect = ref.attention_ref(
        q, k, v, causal=causal, window=window, logit_softcap=cap
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_blocksize_invariance():
    """Same result across block shapes (pipeline correctness)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [
        np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bk,
                                   interpret=True))
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    # (B, Hq, Hkv, S, D, window, softcap)
    (2, 4, 4, 256, 64, None, None),
    (2, 8, 2, 256, 64, None, None),
    (1, 4, 1, 512, 32, None, None),
    (2, 4, 2, 256, 64, 64, None),
    (1, 4, 4, 256, 64, None, 50.0),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(case, dtype):
    b, hq, hkv, s, d, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype=dtype)
    kc = jax.random.normal(ks[1], (b, hkv, s, d), dtype=dtype)
    vc = jax.random.normal(ks[2], (b, hkv, s, d), dtype=dtype)
    lengths = jnp.asarray([s // 2, s][:b] if b <= 2 else [s] * b, jnp.int32)
    out = decode_attention(
        q, kc, vc, lengths, window=window, logit_softcap=cap,
        block_k=64, interpret=True,
    )
    expect = ref.decode_attention_ref(
        q, kc, vc, lengths, window=window, logit_softcap=cap
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )


def test_decode_attention_short_lengths():
    """Blocks past `length` must be skipped, not just masked."""
    b, hq, hkv, s, d = 3, 4, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, hkv, s, d))
    vc = jax.random.normal(ks[2], (b, hkv, s, d))
    lengths = jnp.asarray([1, 65, 512], jnp.int32)
    out = decode_attention(q, kc, vc, lengths, block_k=64, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5
    )
