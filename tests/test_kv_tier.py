"""The SSD-backed KV tier over the real device pipeline.

The tentpole invariants: decode faults are *page-table-driven* reads of
each page's LBA run, demoted hot-window pages are written back through
the same submit path, and the bytes a fault gathers equal the live
pool's contents bit-exactly (the tier never fabricates data).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import StorageClient
from repro.core.types import CacheConfig, EngineConfig, SSDConfig
from repro.models.config import ModelConfig
from repro.serving import kv_tier, paged_kv as pk

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, d_head=8, d_ff=64, vocab=128,
                   dtype="float32")
SSD = SSDConfig(t_max_iops=1e6, l_min_us=20.0, n_instances=32,
                num_blocks=1 << 12)
ECFG = EngineConfig(num_units=4, fetch_width=64)


def _prefilled(tier, batch, start_len, n_steps):
    """Tier with a synthetic prefill flushed to flash (clock > 0)."""
    storage = StorageClient(SSD, ECFG)
    pcfg = kv_tier.paged_cfg_for(TINY, tier, batch, start_len, n_steps)
    layers = TINY.n_layers
    nb = pk.page_blocks(pcfg, tier.block_bytes)
    region = pcfg.n_pages * nb
    state = kv_tier.init_tier(storage, pcfg, tier, batch, 1 << 12)
    kv = state.kv
    for t in range(start_len):
        k, v = kv_tier._synth_kv(pcfg, batch, jnp.int32(t))
        kv = pk.append_token(kv, pcfg, k, v)
    state = dataclasses.replace(state, kv=kv)
    state = kv_tier.prefill_flush(state, storage, pcfg, tier, layers,
                                  region)
    return storage, pcfg, layers, region, state


def test_faulted_bytes_equal_evicted_pool_contents():
    """paged_kv <-> kv_tier integration: a decode step's gathered fault
    rows reproduce the pool pages the prefill flush / demotions evicted,
    and a demotion's flash rows equal its pool page's block image."""
    tier = kv_tier.KVTierConfig(page_tokens=8, hot_window=16,
                                gpu_step_us=10.0)
    batch, start_len = 2, 31   # lengths cross a page boundary at step 0
    storage, pcfg, layers, region, state = _prefilled(
        tier, batch, start_len, 4
    )
    assert float(state.clock) > 0.0   # flush completion advanced it

    nb = pk.page_blocks(pcfg, tier.block_bytes)
    bv = kv_tier.region_block_values(pcfg, tier)
    for i in range(3):
        cold_before = pk.cold_page_mask(state.kv, pcfg, tier.hot_pages)
        k, v = kv_tier._synth_kv(pcfg, batch, jnp.int32(start_len + i))
        state, stats = kv_tier.tier_step(
            state, storage, pcfg, tier, layers, region, k, v,
            jnp.int32(i),
        )
        assert float(stats["data_err"]) == 0.0
        assert float(stats["storage_us"]) > 0.0
        # Clock advances by max(gpu, storage) — never stale.
        assert float(stats["step_us"]) >= tier.gpu_step_us

        # Every newly demoted page's flash run now equals its pool
        # page's packed block image, in every layer region.
        demoted = (
            pk.cold_page_mask(state.kv, pcfg, tier.hot_pages)
            & ~cold_before
        )
        packed = np.asarray(pk.pack_pages(state.kv, pcfg, bv))
        flash = np.asarray(state.flash)
        table = np.asarray(state.kv.page_table)
        for b, mp in zip(*np.nonzero(np.asarray(demoted))):
            phys = table[b, mp]
            for layer in range(layers):
                run = flash[
                    layer * region + phys * nb:
                    layer * region + (phys + 1) * nb
                ]
                np.testing.assert_array_equal(run, packed[phys])


def test_decode_tokens_scale_with_iops_and_roundtrip():
    tier = kv_tier.KVTierConfig(page_tokens=16, hot_window=32,
                                gpu_step_us=20.0)
    slow = SSD.replace(t_max_iops=2e5)
    fast = SSD.replace(t_max_iops=4e6)
    r_slow = kv_tier.decode_tokens_per_s(
        TINY, tier, slow, ECFG, batch=2, start_len=128, n_steps=4
    )
    r_fast = kv_tier.decode_tokens_per_s(
        TINY, tier, fast, ECFG, batch=2, start_len=128, n_steps=4
    )
    assert r_fast["tokens_per_s"] > 2 * r_slow["tokens_per_s"]
    assert r_slow["data_check_max_abs"] == 0.0
    assert r_fast["data_check_max_abs"] == 0.0
    assert r_slow["blocks_per_step"] > 0


def test_striped_array_tier_and_bulk_tenant():
    """num_devices > 1 stripes the mixed op batch over the array; a
    background bulk-ingest stream under the prefill tenant prices but
    never corrupts the decode tenant's data path."""
    tier = kv_tier.KVTierConfig(page_tokens=16, hot_window=32,
                                gpu_step_us=20.0, num_devices=2,
                                bulk_blocks_per_step=64)
    r = kv_tier.decode_tokens_per_s(
        TINY, tier, SSD, ECFG, batch=2, start_len=128, n_steps=4
    )
    assert r["data_check_max_abs"] == 0.0
    assert r["tokens_per_s"] > 0


def test_stage0_cache_absorbs_refaults():
    """A large GPU page cache serves re-faulted cold pages at GPU-local
    latency — strictly faster than the uncached tier."""
    tier = kv_tier.KVTierConfig(page_tokens=16, hot_window=32,
                                gpu_step_us=20.0)
    cached = ECFG.replace(
        cache=CacheConfig(enabled=True, num_sets=512, ways=8,
                          readahead=2)
    )
    r0 = kv_tier.decode_tokens_per_s(
        TINY, tier, SSD, ECFG, batch=2, start_len=128, n_steps=4
    )
    r1 = kv_tier.decode_tokens_per_s(
        TINY, tier, SSD, cached, batch=2, start_len=128, n_steps=4
    )
    assert r1["tokens_per_s"] > r0["tokens_per_s"]
    assert r1["data_check_max_abs"] == 0.0
