"""The ready-time-ordered timing lock (PR 9).

Property suite for ``EngineConfig.lock_order``: the stage-2a global
lock may admit service units either in unit-loop (*program*) order or
in order of each unit's epoch *ready time* (post-fabric-TX batch
arrival). Pins:

  * bit-exact degeneration — with ready times monotone in program
    order (single tenant, zero-cost wire, aligned tenants) the stable
    ready-time sort is the identity and ``"ready_time"`` equals
    ``"program"`` bitwise, end to end;
  * lock conservation and completion monotonicity on random misaligned
    epochs (integer-valued costs and ready times, so f32 arithmetic is
    exact and order-independent);
  * the earliest-ready-first makespan bound (1|r_j|C_max is solved by
    earliest-release order): the ready-time lock never finishes the
    epoch later than the program-order lock;
  * full-run pytree parity on the four existing config families with
    ``lock_order="program"`` explicit vs default;
  * the behavior fig29 quantifies: on a misaligned (interleaved-SQ)
    two-tenant WFQ mix the ready-time lock strictly lowers the latency
    tenant's p99.

Runs under ``hypothesis`` when installed; otherwise the same property
bodies sweep a fixed seed grid (the container image does not ship
hypothesis, and the suite must not silently shrink coverage there).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, timing
from repro.core.device import DevicePipeline, acquire_lock, make_direct_batch
from repro.core.epoch import Epoch, admission_row_order, unit_ready_order
from repro.core.types import (
    CacheConfig,
    EngineConfig,
    FabricConfig,
    PlatformModel,
    QPConfig,
    SSDConfig,
    WorkloadConfig,
)
from repro.workloads.generators import MultiTenant

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - image has no hypothesis
    HAVE_HYPOTHESIS = False


def seeded_property(max_examples: int = 30):
    """``@given(integers)`` when hypothesis exists, seed grid otherwise."""

    def deco(body):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(st.integers(0, 2**31 - 1))(body)
            )
        return pytest.mark.parametrize(
            "seed", range(max_examples)
        )(body)

    return deco


# Integer lock costs: with integral ready times every scan value stays
# an exact small-integer f32, so equalities below are order-independent
# (no rounding to hide behind).
PLAT = PlatformModel(lock_per_req_us=1.0, lock_per_batch_us=3.0)
SSD = SSDConfig(t_max_iops=1e6, l_min_us=20.0, n_instances=32,
                num_blocks=1 << 10)


def _cfg(order: str, mode: str = "aggregated") -> EngineConfig:
    return EngineConfig(num_sqs=8, sq_depth=64, num_units=4,
                        fetch_width=32, mode=mode, lock_order=order)


def _random_epoch(seed: int):
    """A random direct-layout epoch with integral ready times."""
    rng = np.random.default_rng(seed)
    u = int(rng.integers(2, 9))
    w = int(rng.integers(1, 7))          # rows per unit (uniform width)
    n = u * w
    unit = jnp.repeat(jnp.arange(u, dtype=jnp.int32), w)
    ready = jnp.asarray(rng.integers(0, 64, n), jnp.float32)
    valid = jnp.asarray(rng.random(n) > 0.25)
    return Epoch(arrival=ready, ready=ready,
                 tenant=jnp.zeros((n,), jnp.int32), valid=valid,
                 unit=unit, layout="direct"), u


@seeded_property()
@pytest.mark.parametrize("mode", ["aggregated", "per_request"])
def test_lock_conservation_and_monotonicity(mode, seed):
    """On random misaligned epochs, under BOTH orders: every unit's
    grant covers its ready time plus its own cost, grants never overlap
    (non-decreasing by at least the acquired unit's cost along the
    acquisition order), and the epoch's lock makespan accounts for the
    whole cost mass."""
    ep, u = _random_epoch(seed)
    t0 = jnp.float32(float(seed % 7))
    ready_u = np.asarray(ep.unit_ready(u))
    counts = np.asarray(ep.unit_counts(u))
    if mode == "per_request":
        cost = counts.astype(np.float32) * 1.0
    else:
        cost = np.where(counts > 0, 3.0, 0.0).astype(np.float32)

    for order in ("program", "ready_time"):
        end, done, unit_order = acquire_lock(
            t0, ep, u, _cfg(order, mode), PLAT
        )
        end, done = float(end), np.asarray(done)
        acq = (
            np.arange(u) if unit_order is None else np.asarray(unit_order)
        )
        # Completion monotonicity + per-unit lower bound.
        granted = done[acq]
        assert np.all(granted >= ready_u[acq] + cost[acq])
        assert np.all(np.diff(granted) >= cost[acq][1:])
        assert granted[0] >= float(t0) + cost[acq][0]
        # Conservation: the lock is busy for every unit's cost.
        assert end == granted[-1] == np.max(done)
        assert end >= float(t0) + np.sum(cost)


@seeded_property()
def test_ready_time_is_earliest_release_schedule(seed):
    """The ready-time order is the 1|r_j|C_max-optimal earliest-release
    schedule: its lock makespan never exceeds the program order's."""
    ep, u = _random_epoch(seed)
    t0 = jnp.float32(0.0)
    end_p, _, _ = acquire_lock(t0, ep, u, _cfg("program"), PLAT)
    end_r, _, _ = acquire_lock(t0, ep, u, _cfg("ready_time"), PLAT)
    assert float(end_r) <= float(end_p)


@seeded_property()
def test_monotone_ready_degenerates_to_program_bitwise(seed):
    """With per-unit ready times monotone in program order the stable
    sort is the identity: both orders produce bitwise-identical grants
    (the stronger statement behind the aligned-config parity runs)."""
    ep, u = _random_epoch(seed)
    # Force monotone *batch* readiness (the actual premise): sort the
    # per-unit maxima and assign them to every row. All rows must be
    # valid — an empty unit's batch_ready collapses to 0 wherever it
    # sits, which legitimately breaks monotonicity (and the orders then
    # really do differ in the empty unit's irrelevant grant).
    ready_u = jnp.sort(ep.unit_ready(u))
    ep = dataclasses.replace(
        ep, ready=ready_u[ep.unit], arrival=ready_u[ep.unit],
        valid=jnp.ones((ep.capacity,), bool),
    )
    t0 = jnp.float32(2.0)
    end_p, done_p, _ = acquire_lock(t0, ep, u, _cfg("program"), PLAT)
    end_r, done_r, unit_order = acquire_lock(
        t0, ep, u, _cfg("ready_time"), PLAT
    )
    assert bool(jnp.array_equal(end_p, end_r))
    assert bool(jnp.array_equal(done_p, done_r))
    assert bool(
        jnp.array_equal(unit_order, jnp.arange(u, dtype=jnp.int32))
    )


@seeded_property()
def test_admission_row_order_is_block_permutation(seed):
    """The row dispatch order moves whole unit blocks in acquisition
    order and preserves program order inside each block — and the ring
    index-arithmetic form equals the generic argsort form on the ring's
    uniform-width layout."""
    ep, u = _random_epoch(seed)
    order = unit_ready_order(ep.unit_ready(u))
    rows = admission_row_order(order, ep, u)
    rows_np = np.asarray(rows)
    n = ep.capacity
    assert sorted(rows_np.tolist()) == list(range(n))  # permutation
    # Unit blocks appear exactly in acquisition order, rows ascending
    # within each block.
    w = n // u
    dispatched_units = np.asarray(ep.unit)[rows_np].reshape(u, w)
    assert np.array_equal(dispatched_units[:, 0], np.asarray(order))
    assert np.all(np.diff(rows_np.reshape(u, w), axis=1) > 0)
    ring = dataclasses.replace(ep, layout="ring")
    assert np.array_equal(
        np.asarray(admission_row_order(order, ring, u)), rows_np
    )


@seeded_property(max_examples=10)
def test_identity_dispatch_is_bit_exact_in_timing(seed):
    """``timing.update(dispatch_order=identity)`` must be bitwise the
    no-permutation path — the gather/scatter wrapper may not touch a
    float (the FMA-contraction contract)."""
    rng = np.random.default_rng(seed)
    n = 32
    batch = make_direct_batch(
        jnp.asarray(rng.integers(0, 1 << 10, n), jnp.int32),
        jnp.asarray(rng.uniform(0.0, 9.0, n), jnp.float32),
        jnp.asarray(rng.random(n) > 0.2),
    )
    ts = DevicePipeline(_cfg("program"), SSD, PLAT).init_state().tstate
    ts1, c1 = timing.update(ts, batch, SSD, "aggregated")
    ts2, c2 = timing.update(
        ts, batch, SSD, "aggregated",
        dispatch_order=jnp.arange(n, dtype=jnp.int32),
    )
    assert bool(jnp.array_equal(c1, c2))
    for a, b in zip(jax.tree.leaves(ts1), jax.tree.leaves(ts2)):
        assert bool(jnp.array_equal(a, b))


@pytest.mark.parametrize("mode", ["aggregated", "per_request"])
def test_process_monotone_ready_bit_exact_across_orders(mode):
    """End-to-end through DevicePipeline.process: with crafted monotone
    per-unit fetch times the two lock orders are pytree-bit-exact."""
    for order_flag in [False, True]:
        cfg_p, cfg_r = _cfg("program", mode), _cfg("ready_time", mode)
        pipe_p = DevicePipeline(cfg_p, SSD, PLAT)
        pipe_r = DevicePipeline(cfg_r, SSD, PLAT)
        n = 32
        rng = np.random.default_rng(3)
        t = jnp.asarray(rng.uniform(0.0, 4.0, n), jnp.float32)
        valid = jnp.asarray(rng.random(n) > 0.1)
        batch = make_direct_batch(
            jnp.asarray(rng.integers(0, 1 << 10, n), jnp.int32), t, valid
        )
        st, fetch_done, unit = pipe_p._fetch_direct(
            pipe_p.init_state(), t, valid
        )
        if order_flag:
            # Monotone ready times: sort rows' fetch times unit-major.
            fetch_done = jnp.sort(fetch_done)
        out_p = pipe_p.process(st, batch, fetch_done, unit)
        out_r = pipe_r.process(st, batch, fetch_done, unit)
        if order_flag:
            for a, b in zip(jax.tree.leaves(out_p), jax.tree.leaves(out_r)):
                assert bool(jnp.array_equal(a, b))
        else:
            # Unsorted fetch times need not match — but both must obey
            # the per-request lower bound.
            for out in (out_p, out_r):
                res = out[2]
                assert bool(jnp.all(
                    jnp.where(valid, res.target >= res.arrival, True)
                ))


def test_process_misaligned_ready_time_changes_admission():
    """A late bulk unit early in program order delays every later unit
    under the program lock; the ready-time lock admits the ready units
    first (strictly earlier min completion)."""
    cfg_p, cfg_r = _cfg("program"), _cfg("ready_time")
    pipe_p, pipe_r = (
        DevicePipeline(cfg_p, SSD, PLAT), DevicePipeline(cfg_r, SSD, PLAT)
    )
    n, u = 32, 4
    lba = jnp.arange(n, dtype=jnp.int32)
    t = jnp.zeros((n,), jnp.float32)
    valid = jnp.ones((n,), bool)
    batch = make_direct_batch(lba, t, valid)
    st, _, unit = pipe_p._fetch_direct(pipe_p.init_state(), t, valid)
    # Unit 0's batch lands very late, units 1..3 are ready at ~0.
    fetch_done = jnp.where(unit == 0, 500.0, 1.0 + unit.astype(jnp.float32))
    _, _, res_p = pipe_p.process(st, batch, fetch_done, unit)
    _, _, res_r = pipe_r.process(st, batch, fetch_done, unit)
    first_p = float(jnp.min(jnp.where(valid, res_p.target, 1e30)))
    first_r = float(jnp.min(jnp.where(valid, res_r.target, 1e30)))
    assert first_r < first_p
    # Program order stalls every unit behind unit 0's 500us arrival.
    assert first_p >= 500.0
    assert first_r < 500.0


# -- full-run parity on the four existing config families ------------------

SMALL = dict(num_sqs=8, sq_depth=64, fetch_width=16)
FAMILIES = {
    "baseline_dp": (
        EngineConfig(batched_datapath=False, **SMALL),
        WorkloadConfig(io_depth=16, read_frac=0.8),
    ),
    "remote_qos": (
        EngineConfig(fabric=FabricConfig(
            remote=True, tx_bytes_per_us=10_000.0,
            rx_bytes_per_us=10_000.0, rtt_us=2.0, wire_txn_us=0.1,
            mtu_batch=4, mtu_timeout_us=5.0,
            switch_bytes_per_us=20_000.0, switch_fanin=4,
            qos_weights=(2.0, 1.0)), **SMALL),
        MultiTenant(io_depth=16),
    ),
    "qp_coalesced": (
        EngineConfig(qp=QPConfig(
            cq_coalesce_n=4, cq_coalesce_us=5.0, cq_doorbell_us=0.2,
            cq_poll_us=0.1, cqe_reap_us=0.05), **SMALL),
        WorkloadConfig(io_depth=16, read_frac=0.8),
    ),
    "cached": (
        EngineConfig(cache=CacheConfig(
            enabled=True, num_sets=8, ways=2, chase=2, readahead=1),
            **SMALL),
        WorkloadConfig(io_depth=16, read_frac=0.8),
    ),
}


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_full_run_parity_program_lock(name):
    """``lock_order="program"`` is the default and the pre-refactor
    path: an explicit setting must reproduce the default run leaf for
    leaf (the seed-parity anchor — the refactor moved the lock onto the
    epoch struct without disturbing one bit of the program order)."""
    cfg, wl = FAMILIES[name]
    assert cfg.lock_order == "program"   # the default
    explicit = dataclasses.replace(cfg, lock_order="program")
    st1 = engine.simulate(cfg, SSDConfig(), wl, rounds=4)
    st2 = engine.simulate(explicit, SSDConfig(), wl, rounds=4)
    p1, _ = jax.tree_util.tree_flatten_with_path(st1)
    p2, _ = jax.tree_util.tree_flatten_with_path(st2)
    for (k1, a), (k2, b) in zip(p1, p2):
        assert k1 == k2
        assert bool(jnp.array_equal(a, b)), jax.tree_util.keystr(k1)


def test_misaligned_wfq_ready_time_lowers_latency_p99():
    """The fig29 behavior at test scale: interleaved two-tenant WFQ mix
    on a TX-bound wire — the ready-time lock strictly lowers the
    latency tenant's p99 and never raises the bulk tenant's."""
    wl = MultiTenant(io_depth=32, tenant_read_frac=(1.0, 0.0),
                     interleave=True)
    ssd = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64)
    p99 = {}
    for order in ("program", "ready_time"):
        cfg = EngineConfig(
            num_sqs=8, num_units=8, sq_depth=64, fetch_width=32,
            fabric=FabricConfig(remote=True, tx_bytes_per_us=400.0,
                                rx_bytes_per_us=16000.0,
                                qos_weights=(2.0, 1.0)),
            lock_order=order,
        )
        m = engine.simulate(cfg, ssd, wl, rounds=24).metrics
        p99[order] = np.asarray(m.tenant_p99_us())
    assert p99["ready_time"][0] < p99["program"][0]
    assert p99["ready_time"][1] <= p99["program"][1] * 1.01


def test_tenant_metrics_accessors():
    """tenant_lat_hist rows account for exactly the device completions
    (cache hits excluded), p99 >= p50, and SLO attainment is a sane
    fraction with empty classes reporting 1.0."""
    wl = MultiTenant(io_depth=16, tenant_read_frac=(1.0, 0.0))
    cfg = EngineConfig(fabric=FabricConfig(
        remote=True, tx_bytes_per_us=2000.0, rx_bytes_per_us=2000.0,
        qos_weights=(1.0, 1.0)), **SMALL)
    m = engine.simulate(cfg, SSDConfig(), wl, rounds=8).metrics
    np.testing.assert_allclose(
        np.asarray(m.tenant_lat_hist.sum(axis=1)),
        np.asarray(m.tenant_completed), rtol=1e-6,
    )
    p50, p99 = m.tenant_p50_us(), m.tenant_p99_us()
    assert bool(jnp.all(p99 >= p50))
    slo = np.asarray(m.slo_attainment(1e9))
    np.testing.assert_allclose(slo, 1.0)   # everything under a huge SLO
    assert np.all((np.asarray(m.slo_attainment(1.0)) >= 0.0)
                  & (np.asarray(m.slo_attainment(1.0)) <= 1.0))
    # An empty tenant class has missed nothing.
    z = engine.Metrics.zero(3)
    np.testing.assert_allclose(np.asarray(z.slo_attainment(100.0)), 1.0)


def test_lock_order_validation():
    with pytest.raises(ValueError, match="lock_order"):
        EngineConfig(lock_order="alphabetical")
