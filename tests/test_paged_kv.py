"""Paged KV cache: equivalence with dense caches + SSD-tier pricing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import ClientState, StorageClient
from repro.core.types import EngineConfig, SSDConfig
from repro.serving import paged_kv as pk


def test_append_and_gather_matches_dense():
    cfg = pk.PagedKVConfig(page_tokens=4, n_pages=64, max_pages=8,
                           kv_heads=2, head_dim=8, dtype="float32")
    b, steps = 3, 13
    kv = pk.init_paged(cfg, b)
    ks = jax.random.split(jax.random.PRNGKey(0), steps * 2)
    dense_k = np.zeros((b, 2, cfg.max_pages * 4, 8), np.float32)
    dense_v = np.zeros_like(dense_k)
    append = jax.jit(lambda kv, k, v: pk.append_token(kv, cfg, k, v))
    for t in range(steps):
        k_new = jax.random.normal(ks[2 * t], (b, 2, 8))
        v_new = jax.random.normal(ks[2 * t + 1], (b, 2, 8))
        kv = append(kv, k_new, v_new)
        dense_k[:, :, t] = np.asarray(k_new)
        dense_v[:, :, t] = np.asarray(v_new)
    gk, gv = pk.gather_dense(kv, cfg)
    np.testing.assert_allclose(np.asarray(gk), dense_k, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gv), dense_v, rtol=1e-6)
    assert int(kv.free_head) == b * ((steps + 3) // 4)


def test_no_cross_sequence_page_sharing():
    cfg = pk.PagedKVConfig(page_tokens=2, n_pages=32, max_pages=4,
                           kv_heads=1, head_dim=4, dtype="float32")
    kv = pk.init_paged(cfg, 2)
    for t in range(4):
        k = jnp.stack([jnp.full((1, 4), 10 + t), jnp.full((1, 4), 20 + t)])
        kv = pk.append_token(kv, cfg, k, k)
    table = np.asarray(kv.page_table)
    used0 = set(table[0][table[0] >= 0].tolist())
    used1 = set(table[1][table[1] >= 0].tolist())
    assert used0.isdisjoint(used1)


def test_cold_page_faults_priced_by_device():
    cfg = pk.PagedKVConfig(page_tokens=4, n_pages=128, max_pages=16,
                           kv_heads=2, head_dim=16, dtype="bfloat16")
    kv = pk.init_paged(cfg, 4)
    for t in range(40):
        k = jnp.ones((4, 2, 16), jnp.bfloat16)
        kv = pk.append_token(kv, cfg, k, k)
    slow = SSDConfig(t_max_iops=1e5, l_min_us=50.0, n_instances=16,
                     num_blocks=1 << 12)
    fast = slow.replace(t_max_iops=4e6, n_instances=256)
    ecfg = EngineConfig(num_units=4, fetch_width=64)
    flash = jnp.ones((1 << 12, 64))
    times = {}
    for name, ssd in (("slow", slow), ("fast", fast)):
        client = StorageClient(ssd, ecfg)
        cstate = ClientState.init(ssd, 4)
        _, done = pk.fault_pages_virtual_time(
            kv, cfg, client, cstate, flash, jnp.float32(0)
        )
        times[name] = float(done)
    assert times["slow"] > 2 * times["fast"], times
