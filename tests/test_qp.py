"""Queue-pair layer tests: CQ posting/reaping, coalescing, parity.

Contracts under test:
  * neutral QPConfig is an exact no-op — ``reaped == done`` bit-exactly,
    so PR-2-era completion times reproduce (the acceptance parity bar);
  * every QP knob only ever adds time;
  * completion coalescing trades doorbell rate for delivered IOPS;
  * the client's SQ/CQ ring path reproduces ``engine_round`` completion
    times bit-exactly for the same request stream;
  * ``latency_bucket``/``hist_percentile`` edge cases.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, frontend
from repro.core.client import StorageClient
from repro.core.device import DevicePipeline, make_direct_batch
from repro.core.engine import (
    HIST_BUCKETS,
    hist_percentile,
    latency_bucket,
)
from repro.core.qp import CQRings, post_and_reap
from repro.core.types import (
    EngineConfig,
    PlatformModel,
    QPConfig,
    SSDConfig,
    WorkloadConfig,
)
from repro import workloads

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)


# ---------------------------------------------------------------------------
# Stage-5 unit behavior.
# ---------------------------------------------------------------------------

def _toy_completions(n=32, q=4):
    cq_id = jnp.arange(n, dtype=jnp.int32) % q
    done = 100.0 + jnp.arange(n, dtype=jnp.float32) * 3.0
    req_id = jnp.arange(n, dtype=jnp.int32)
    valid = jnp.ones((n,), bool)
    return cq_id, done, req_id, valid


def test_neutral_qp_is_transparent():
    """Neutral config: reaped == done bit-exactly, entries recorded."""
    cq = CQRings.empty(4, 64)
    cq_id, done, req_id, valid = _toy_completions()
    cq2, reaped = post_and_reap(cq, cq_id, done, req_id, valid, QPConfig())
    np.testing.assert_array_equal(np.asarray(reaped), np.asarray(done))
    assert (np.asarray(cq2.tail) == 8).all()
    assert (np.asarray(cq2.head) == 8).all()     # consumer drained all
    assert (np.asarray(cq2.bell_time) == 0.0).all()


def test_qp_knobs_only_add_time():
    """Any non-neutral knob yields reaped >= done for every valid row."""
    cq_id, done, req_id, valid = _toy_completions()
    for qp in [
        QPConfig(cq_doorbell_us=0.7),
        QPConfig(cq_poll_us=1.1),
        QPConfig(cqe_reap_us=0.2),
        QPConfig(cq_coalesce_n=4, cq_coalesce_us=50.0),
        QPConfig(cq_coalesce_n=8, cq_coalesce_us=5.0, cq_doorbell_us=0.5,
                 cq_poll_us=0.3, cqe_reap_us=0.05),
    ]:
        cq = CQRings.empty(4, 64)
        _, reaped = post_and_reap(cq, cq_id, done, req_id, valid, qp)
        assert (np.asarray(reaped) >= np.asarray(done) - 1e-6).all(), qp


def test_coalescing_groups_wait_for_doorbell():
    """n completions share one doorbell: early members wait for the
    group's last completion (bounded by the coalescing timer)."""
    n, q = 16, 1
    cq_id = jnp.zeros((n,), jnp.int32)
    done = 100.0 + jnp.arange(n, dtype=jnp.float32)  # 1us apart
    qp = QPConfig(cq_coalesce_n=4, cq_coalesce_us=1e6)
    cq = CQRings.empty(q, 64)
    _, reaped = post_and_reap(
        cq, cq_id, done, jnp.arange(n, dtype=jnp.int32),
        jnp.ones((n,), bool), qp,
    )
    r = np.asarray(reaped).reshape(4, 4)
    # Every member of a group observes the group's last completion time.
    np.testing.assert_allclose(r, r[:, -1:].repeat(4, axis=1), rtol=1e-6)
    # Timer bound: a tight cq_coalesce_us caps the wait.
    qp_t = QPConfig(cq_coalesce_n=4, cq_coalesce_us=1.5)
    _, reaped_t = post_and_reap(
        CQRings.empty(q, 64), cq_id, done,
        jnp.arange(n, dtype=jnp.int32), jnp.ones((n,), bool), qp_t,
    )
    assert (np.asarray(reaped_t) <= np.asarray(done) + 1.5 + 1e-5).all()


def test_poll_and_reap_costs_are_charged():
    cq_id, done, req_id, valid = _toy_completions()
    qp = QPConfig(cq_poll_us=2.0, cqe_reap_us=0.5)
    _, reaped = post_and_reap(
        CQRings.empty(4, 64), cq_id, done, req_id, valid, qp
    )
    assert (np.asarray(reaped) >= np.asarray(done) + 2.5 - 1e-6).all()


def test_invalid_rows_untouched():
    cq_id, done, req_id, valid = _toy_completions()
    valid = valid.at[::2].set(False)
    qp = QPConfig(cq_coalesce_n=2, cq_doorbell_us=1.0)
    cq2, reaped = post_and_reap(
        CQRings.empty(4, 64), cq_id, done, req_id, valid, qp
    )
    assert (np.asarray(reaped)[::2] == 0.0).all()
    assert int(np.asarray(cq2.tail).sum()) == int(valid.sum())


# ---------------------------------------------------------------------------
# Engine-level parity + coalescing economics.
# ---------------------------------------------------------------------------

def test_engine_neutral_qp_matches_no_cq_pipeline():
    """process with a CQ under the neutral config == process with no CQ
    (the pre-QP pipeline), bit-exactly — state and completions."""
    import jax

    plat = PlatformModel()
    pipe = DevicePipeline(CFG, SSD, plat)
    n = 256
    batch = make_direct_batch(
        (jnp.arange(n, dtype=jnp.int32) * 17) % SSD.num_blocks,
        jnp.float32(1.0),
    )
    st = pipe.init_state()
    st1, fetch_done, unit = pipe._fetch_direct(
        st, batch.arrival, batch.valid
    )
    out_cq, cq, res_cq = pipe.process(st1, batch, fetch_done, unit,
                                      pipe.init_cq())
    out_no, none_cq, res_no = pipe.process(st1, batch, fetch_done, unit)
    assert none_cq is None
    np.testing.assert_array_equal(
        np.asarray(res_cq.reaped), np.asarray(res_no.done)
    )
    for a, b in zip(jax.tree.leaves(out_cq), jax.tree.leaves(out_no)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cq_rings_track_completions_per_round():
    """Engine CQ tails advance by exactly the completed count and the
    consumer reaps everything it posts."""
    wl = WorkloadConfig(io_depth=16)
    out = engine.simulate(CFG, SSD, wl, rounds=12)
    posted = int(np.asarray(out.cq.tail).sum())
    assert posted == int(float(out.metrics.completed))
    np.testing.assert_array_equal(np.asarray(out.cq.head),
                                  np.asarray(out.cq.tail))


def test_coalescing_recovers_doorbell_throughput():
    """With a doorbell cost, 1 completion/doorbell throttles IOPS; deeper
    coalescing recovers toward the neutral ceiling."""
    wl = WorkloadConfig(io_depth=256)
    def run(qp):
        return float(
            engine.simulate(CFG.replace(qp=qp), SSD, wl, rounds=24)
            .metrics.iops()
        )

    qp1 = QPConfig(cq_coalesce_n=1, cq_doorbell_us=2.0)
    qp16 = QPConfig(cq_coalesce_n=16, cq_coalesce_us=100.0,
                    cq_doorbell_us=2.0)
    neutral = run(QPConfig())
    assert run(qp1) < run(qp16) <= neutral * 1.001


# ---------------------------------------------------------------------------
# Ring-path parity: StorageClient == engine_round, bit-exactly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qp", [
    QPConfig(),
    QPConfig(cq_coalesce_n=4, cq_coalesce_us=40.0, cq_doorbell_us=0.5,
             cq_poll_us=0.3, cqe_reap_us=0.05),
])
def test_client_ring_path_reproduces_engine_round(qp):
    """StorageClient via SQ/CQ reproduces engine_round completion times
    bit-exactly for the same request stream (same per-SQ entries)."""
    cfg = CFG.replace(fetch_width=64, qp=qp)
    plat = PlatformModel()
    n, t0 = 256, 2.0
    lba = (jnp.arange(n, dtype=jnp.int32) * 37) % SSD.num_blocks

    client = StorageClient(SSD, cfg, plat)
    flash = jnp.ones((SSD.num_blocks, 8))
    _, _, done_client = client.read(
        client.init_state(), flash, lba, jnp.float32(t0)
    )

    # The same stream through the engine: a trace laid out so TraceReplay
    # deals each SQ the exact per-SQ (time, lba) sequence the client's
    # deal produced (both deal time-sorted rank r; the trace is permuted
    # so rank r of SQ s matches).
    q = cfg.num_sqs
    sq = np.asarray(frontend.deal_sqs(n, cfg))
    order = np.lexsort((np.arange(n), sq))
    per_sq = [list(order[sq[order] == s]) for s in range(q)]
    trace_idx = np.array([per_sq[j % q][j // q] for j in range(n)])
    wl = workloads.TraceReplay.from_trace(
        np.full(n, t0, np.float32), np.asarray(lba)[trace_idx],
        np.zeros(n), cfg,
    )
    st = engine.init_state(cfg, SSD, wl)
    st = dataclasses.replace(st, clock=jnp.float32(t0))
    out = engine.engine_round(st, cfg, SSD, wl, plat)
    m = out.metrics

    assert float(m.completed) == n
    assert float(m.last_completion) == float(jnp.max(done_client))
    assert float(m.sum_e2e) == float(jnp.sum(done_client - t0))
    hist_client = np.bincount(
        np.asarray(latency_bucket(done_client - t0)),
        minlength=HIST_BUCKETS,
    )
    np.testing.assert_array_equal(
        hist_client, np.asarray(m.lat_hist).astype(int)
    )


# ---------------------------------------------------------------------------
# latency_bucket / hist_percentile edge cases.
# ---------------------------------------------------------------------------

def test_latency_bucket_edges():
    lat = jnp.asarray([0.0, 1e-9, 1.0, 1e5, 1e30], jnp.float32)
    b = np.asarray(latency_bucket(lat))
    assert b[0] == 0 and b[1] == 0 and b[2] == 0     # clamp below floor
    assert b[3] == HIST_BUCKETS - 1                  # top of range
    assert b[4] == HIST_BUCKETS - 1                  # overflow clamps
    mono = np.asarray(
        latency_bucket(jnp.logspace(0, 5, 50, dtype=jnp.float32))
    )
    assert (np.diff(mono) >= 0).all()


def test_hist_percentile_empty_histogram():
    """All-zero histogram degrades to the first bucket's midpoint (no
    NaN/inf), for any q."""
    h = jnp.zeros((HIST_BUCKETS,), jnp.float32)
    for q in (0.0, 0.5, 1.0):
        v = float(hist_percentile(h, q))
        assert np.isfinite(v) and v > 0.0
    assert float(hist_percentile(h, 0.5)) == float(hist_percentile(h, 0.99))


def test_hist_percentile_q_extremes_and_single_bucket():
    h = jnp.zeros((HIST_BUCKETS,), jnp.float32).at[7].set(42.0)
    p_lo = float(hist_percentile(h, 0.0))
    p_mid = float(hist_percentile(h, 0.5))
    p_hi = float(hist_percentile(h, 1.0))
    # q=0: cumsum >= 0 is true at bucket 0; q>0 finds the single bucket.
    assert p_lo == float(hist_percentile(jnp.ones_like(h), 0.0))
    assert p_mid == p_hi
    lo_edge = 10 ** (7 * 5.0 / HIST_BUCKETS)
    hi_edge = 10 ** (8 * 5.0 / HIST_BUCKETS)
    assert lo_edge <= p_mid <= hi_edge


def test_hist_percentile_pools_device_axis():
    h = jnp.zeros((3, HIST_BUCKETS), jnp.float32).at[:, 5].set(1.0)
    single = jnp.zeros((HIST_BUCKETS,), jnp.float32).at[5].set(3.0)
    assert float(hist_percentile(h, 0.9)) == float(
        hist_percentile(single, 0.9)
    )
