"""repro-lint rule coverage: one known-good and one known-bad fixture
per rule (RL001-RL006), the PR-8 ``Metrics.zero`` regression, the RL002
reassociation rejection, suppression comments, and the acceptance gate
that the shipped tree itself lints clean.

Fixtures are in-memory source strings through ``lint_source`` — the
linter is pure AST work, so none of this imports jax.
"""
import textwrap
from pathlib import Path

from tools.repro_lint import fingerprint_source, lint_source
from tools.repro_lint.engine import lint_paths

ROOT = Path(__file__).resolve().parents[1]


def _rules(violations):
    return {v.rule for v in violations}


def lint(src, relpath="src/repro/core/example.py", lock=None):
    return lint_source(textwrap.dedent(src), relpath, lock=lock)


# ---------------------------------------------------------------------------
# RL001: weak-typed pytree leaf
# ---------------------------------------------------------------------------

# The PR-8 bug, reduced: a python-float leaf in Metrics.zero made the
# zero state's aval weak-typed while the runner's output was strongly
# typed f32 — so the first timed rep silently recompiled the runner.
PR8_METRICS_ZERO = """
import dataclasses
import jax
import jax.numpy as jnp

FAR = 3e38


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Metrics:
    completed: jax.Array
    first_submit: jax.Array

    @staticmethod
    def zero():
        return Metrics(
            completed=jnp.float32(0),
            first_submit=FAR,
        )
"""

PR8_METRICS_ZERO_FIXED = PR8_METRICS_ZERO.replace(
    "first_submit=FAR", "first_submit=jnp.float32(FAR)"
)


def test_rl001_pr8_metrics_zero_regression():
    bad = lint(PR8_METRICS_ZERO)
    assert "RL001" in _rules(bad)
    assert any("retrace" in v.message for v in bad)


def test_rl001_strong_typed_is_clean():
    assert _rules(lint(PR8_METRICS_ZERO_FIXED)) == set()


def test_rl001_bare_literal_flagged():
    src = """
    import dataclasses
    import jax

    @jax.tree_util.register_dataclass
    @dataclasses.dataclass(frozen=True)
    class S:
        x: jax.Array

        @staticmethod
        def init():
            return S(x=0.0)
    """
    assert "RL001" in _rules(lint(src))


def test_rl001_unregistered_class_not_flagged():
    # Plain dataclasses are not pytrees — weak leaves cannot retrace.
    src = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class S:
        x: float

        @staticmethod
        def init():
            return S(x=0.0)
    """
    assert _rules(lint(src)) == set()


# ---------------------------------------------------------------------------
# RL002: pinned-expression fingerprint
# ---------------------------------------------------------------------------

PINNED = """
import jax.numpy as jnp


def core(b, rank, sched, lmin, s_arr):
    # repro-lint: pinned-expr demo
    start = b + rank * sched
    comp = jnp.maximum(start + sched, s_arr + lmin)
    # repro-lint: end-pinned-expr
    return comp
"""

# Algebraically equal, different expression tree — the FMA-contraction
# hazard RL002 exists to catch.
REASSOCIATED = PINNED.replace(
    "start = b + rank * sched", "start = (b + rank * sched) * 1.0"
)


def _lock_for(src, relpath="src/repro/core/timing_demo.py"):
    import re

    body = re.search(
        r"pinned-expr demo\n(.*?)\s*# repro-lint: end", src, re.S
    ).group(1)
    return {f"{relpath}::demo": fingerprint_source(textwrap.dedent(body))}


def test_rl002_matching_pin_is_clean():
    src = textwrap.dedent(PINNED)
    rel = "src/repro/core/timing_demo.py"
    assert _rules(lint_source(src, rel, lock=_lock_for(src, rel))) == set()


def test_rl002_reassociated_expression_rejected():
    rel = "src/repro/core/timing_demo.py"
    lock = _lock_for(textwrap.dedent(PINNED), rel)
    bad = lint_source(textwrap.dedent(REASSOCIATED), rel, lock=lock)
    assert "RL002" in _rules(bad)
    assert any("reassociated" in v.message for v in bad)


def test_rl002_comment_and_whitespace_insensitive():
    a = fingerprint_source("x = a + b * c\n")
    b = fingerprint_source("# a comment\nx = (a   +\n     b * c)\n")
    c = fingerprint_source("x = (a + b) * c\n")
    assert a == b
    assert a != c


def test_rl002_unpinned_fence_flagged():
    src = textwrap.dedent(PINNED)
    bad = lint_source(src, "src/repro/core/timing_demo.py", lock={})
    assert "RL002" in _rules(bad)
    assert any("no lock entry" in v.message for v in bad)


def test_rl002_unterminated_fence_flagged():
    src = "# repro-lint: pinned-expr oops\nx = 1\n"
    bad = lint_source(src, "src/repro/core/x.py", lock={})
    assert any(
        v.rule == "RL002" and "unterminated" in v.message for v in bad
    )


# ---------------------------------------------------------------------------
# RL003: sort discipline
# ---------------------------------------------------------------------------

def test_rl003_raw_argsort_flagged():
    src = """
    import jax.numpy as jnp

    def f(x):
        return jnp.argsort(x, stable=True)
    """
    assert "RL003" in _rules(lint(src))


def test_rl003_lax_sort_flagged():
    src = """
    import jax

    def f(x):
        return jax.lax.sort(x)
    """
    assert "RL003" in _rules(lint(src))


def test_rl003_segops_module_exempt():
    src = """
    import jax.numpy as jnp

    def stable_argsort(x):
        return jnp.argsort(x, stable=True)
    """
    assert _rules(lint(src, "src/repro/core/segops.py")) == set()


def test_rl003_list_sort_method_not_flagged():
    src = """
    def f(xs):
        xs.sort()
        return xs
    """
    assert _rules(lint(src)) == set()


# ---------------------------------------------------------------------------
# RL004: scatter/gather bounds mode
# ---------------------------------------------------------------------------

def test_rl004_bare_scatter_flagged():
    src = """
    import jax.numpy as jnp

    def f(x, i, v):
        return x.at[i].set(v)
    """
    assert "RL004" in _rules(lint(src))


def test_rl004_explicit_mode_clean():
    src = """
    import jax.numpy as jnp

    def f(x, i, v):
        y = x.at[i].set(v, mode="drop")
        return y.at[i].add(v, mode="promise_in_bounds")
    """
    assert _rules(lint(src)) == set()


def test_rl004_take_without_mode_flagged():
    src = """
    import jax.numpy as jnp

    def f(x, i):
        return jnp.take(x, i)
    """
    assert "RL004" in _rules(lint(src))


def test_rl004_scoped_to_core():
    src = """
    import jax.numpy as jnp

    def f(x, i, v):
        return x.at[i].set(v)
    """
    assert _rules(lint(src, "src/repro/models/attention.py")) == set()


# ---------------------------------------------------------------------------
# RL005: jit-boundary hygiene
# ---------------------------------------------------------------------------

def test_rl005_wall_clock_reachable_from_runner_flagged():
    src = """
    import time


    def helper(x):
        return time.time() + x


    def make_runner(cfg):
        def _run(state):
            return helper(state)
        return _run
    """
    bad = lint(src)
    assert "RL005" in _rules(bad)
    assert any("make_runner" in v.message for v in bad)


def test_rl005_np_random_in_process_flagged():
    src = """
    import numpy as np


    class DevicePipeline:
        def process(self, state, batch):
            noise = np.random.rand(4)
            return state + noise
    """
    assert "RL005" in _rules(lint(src))


def test_rl005_unreachable_impurity_not_flagged():
    # Host-side timing *outside* the jit entry points is fine (the
    # benchmark drivers do exactly this).
    src = """
    import time


    def bench(runner, state):
        t0 = time.perf_counter()
        runner(state)
        return time.perf_counter() - t0


    def make_runner(cfg):
        def _run(state):
            return state
        return _run
    """
    assert _rules(lint(src)) == set()


# ---------------------------------------------------------------------------
# RL006: deprecated-path ban
# ---------------------------------------------------------------------------

def test_rl006_direct_path_use_flagged():
    src = """
    def go(pipe, state, batch):
        return pipe._submit_direct(state, batch)
    """
    assert "RL006" in _rules(lint(src))


def test_rl006_allowed_in_device_and_tests():
    src = """
    def go(pipe, state, batch):
        return pipe._submit_direct(state, batch)
    """
    assert _rules(lint(src, "src/repro/core/device.py")) == set()
    assert _rules(lint(src, "tests/test_device.py")) == set()


# ---------------------------------------------------------------------------
# Suppression + the shipped tree
# ---------------------------------------------------------------------------

def test_suppression_comment_same_line_and_above():
    src = """
    import jax.numpy as jnp

    def f(x, i, v):
        a = x.at[i].set(v)  # repro-lint: disable=RL004
        # repro-lint: disable=RL004
        b = x.at[i].add(v)
        return a + b
    """
    assert _rules(lint(src)) == set()


def test_suppression_is_per_rule():
    src = """
    import jax.numpy as jnp

    def f(x, i, v):
        return x.at[i].set(v)  # repro-lint: disable=RL003
    """
    assert "RL004" in _rules(lint(src))


def test_shipped_tree_is_clean(monkeypatch):
    """Acceptance gate: `python -m tools.repro_lint src/` exits 0."""
    # Lock keys are repo-root-relative (the CI invocation's cwd), so
    # lint from the root the way the CLI does.
    monkeypatch.chdir(ROOT)
    violations, checked = lint_paths(
        ["src"], lock_path=ROOT / "tools/repro_lint/pinned.lock"
    )
    assert checked > 0
    assert violations == [], "\n".join(v.render() for v in violations)


def test_lockfile_pins_the_timing_expression_trees():
    from tools.repro_lint.pinning import load_lock

    lock = load_lock(ROOT / "tools/repro_lint/pinned.lock")
    # Keys are relative to the repo root (the CI invocation's cwd).
    assert any(
        k.endswith("core/timing.py::sorted-batch-core") for k in lock
    )
    assert any(k.endswith("core/device.py::lock-scan") for k in lock)
