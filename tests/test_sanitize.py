"""checkify sanitizer: clean on real configs, bit-exact when off, and
actually armed (an injected out-of-bounds ring index must raise).

``EngineConfig.sanitize`` threads ``checkify.check`` assertions through
``DevicePipeline.process`` (ring indices in bounds, completion times
monotone and non-negative, valid-mask conservation across the
compaction/admission permutations, flash page and fabric cursor
invariants). The contract tested here:

  * sanitize=True runs checkify-clean on every standard config family;
  * the sanitized run's final state is *bitwise identical* to the
    default run's (checks observe, never transform);
  * a corrupted batch trips the checks (the flag is not inert).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import checkify

from repro.core import engine, frontend
from repro.core.device import DevicePipeline
from repro.core.types import (
    CacheConfig,
    EngineConfig,
    FabricConfig,
    PlatformModel,
    QPConfig,
    SSDConfig,
)
from repro.core.types import WorkloadConfig

SSD = SSDConfig()
PLAT = PlatformModel()
WL = WorkloadConfig(io_depth=16, read_frac=0.8)
SMALL = dict(num_sqs=8, sq_depth=64, fetch_width=16)

# The same four families tests/test_emulator_speed.py pins bit-exactness
# on — together they cover every pipeline branch the sanitizer
# instruments (baseline datapath, switched fabric + WFQ, non-neutral
# QP, sparse cached epochs).
CONFIGS = {
    "baseline_dp": EngineConfig(batched_datapath=False, **SMALL),
    "remote_qos": EngineConfig(
        fabric=FabricConfig(
            remote=True,
            tx_bytes_per_us=10_000.0, rx_bytes_per_us=10_000.0,
            rtt_us=2.0, wire_txn_us=0.1, mtu_batch=4, mtu_timeout_us=5.0,
            switch_bytes_per_us=20_000.0, switch_fanin=4,
            qos_weights=(2.0, 1.0),
        ),
        **SMALL,
    ),
    "qp_coalesced": EngineConfig(
        qp=QPConfig(
            cq_coalesce_n=4, cq_coalesce_us=5.0, cq_doorbell_us=0.2,
            cq_poll_us=0.1, cqe_reap_us=0.05,
        ),
        **SMALL,
    ),
    "cached": EngineConfig(
        cache=CacheConfig(
            enabled=True, num_sets=8, ways=2, chase=2, readahead=1
        ),
        **SMALL,
    ),
}

ROUNDS = 6


def _assert_states_equal(a, b):
    for pa, pb in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        jax.tree_util.tree_flatten_with_path(b)[0],
    ):
        assert jnp.array_equal(pa[1], pb[1]), (
            f"leaf {jax.tree_util.keystr(pa[0])} diverged"
        )


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_sanitized_run_clean_and_bit_exact(name):
    """sanitize=True neither raises nor changes a single bit."""
    cfg = CONFIGS[name]
    st = engine.init_state(cfg, SSD, WL)
    plain = engine.make_runner(cfg, SSD, WL, PLAT, ROUNDS)(st)
    sanitized = engine.make_runner(
        cfg, SSD, WL, PLAT, ROUNDS, sanitize=True
    )(st)
    _assert_states_equal(plain, sanitized)


def test_sanitize_via_config_flag():
    """cfg.sanitize=True is equivalent to make_runner(sanitize=True)."""
    cfg = CONFIGS["baseline_dp"].replace(sanitize=True)
    st = engine.init_state(cfg, SSD, WL)
    out = engine.make_runner(cfg, SSD, WL, PLAT, ROUNDS)(st)
    plain_cfg = CONFIGS["baseline_dp"]
    plain = engine.make_runner(plain_cfg, SSD, WL, PLAT, ROUNDS)(
        engine.init_state(plain_cfg, SSD, WL)
    )
    _assert_states_equal(plain, out)


def test_sanitized_array_runner_clean():
    cfg = EngineConfig(**SMALL)
    st = engine.init_array_state(cfg, SSD, WL, 2)
    plain = engine.make_array_runner(cfg, SSD, WL, PLAT, ROUNDS)(st)
    sanitized = engine.make_array_runner(
        cfg, SSD, WL, PLAT, ROUNDS, sanitize=True
    )(st)
    _assert_states_equal(plain, sanitized)


def test_injected_oob_ring_index_caught():
    """The checks are armed: a valid row with sq_id >= num_sqs raises."""
    cfg = EngineConfig(**SMALL).replace(sanitize=True)
    st = engine.init_state(cfg, SSD, WL)
    pipe = DevicePipeline(cfg, SSD, PLAT)
    unit = frontend.fetch_row_units(cfg)

    _, disp, batch, fetch_done = jax.jit(
        lambda s: frontend.fetch(
            s.rings, s.clock, s.device.disp_time, cfg, PLAT
        )
    )(st)
    dev = dataclasses.replace(st.device, disp_time=disp)
    batch = dataclasses.replace(batch, arrival=fetch_done)

    def go(b):
        return pipe.process(dev, b, fetch_done, unit, st.cq,
                            ring_layout=True)

    checked = jax.jit(
        checkify.checkify(go, errors=checkify.user_checks)
    )

    err, _ = checked(batch)
    assert err.get() is None, err.get()

    bad = dataclasses.replace(
        batch,
        sq_id=batch.sq_id.at[0].set(
            jnp.int32(cfg.num_sqs + 3), mode="drop"
        ),
        valid=batch.valid.at[0].set(True, mode="drop"),
    )
    err, _ = checked(bad)
    assert err.get() is not None
    assert "SQ id" in str(err.get())
