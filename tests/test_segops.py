"""Property tests for the segmented-scan primitives."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep"
)
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segops


@st.composite
def seg_arrays(draw):
    n = draw(st.integers(1, 128))
    vals = draw(
        st.lists(
            st.floats(min_value=-1e4, max_value=1e4, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    return np.asarray(vals, np.float32), np.asarray(heads, bool)


@hypothesis.given(seg_arrays())
@hypothesis.settings(max_examples=25, deadline=None)
def test_segmented_prefix_max(xs):
    vals, heads = xs
    out = np.asarray(
        segops.segmented_prefix_max(jnp.asarray(vals), jnp.asarray(heads))
    )
    ref = np.empty_like(vals)
    run = -np.inf
    for i in range(len(vals)):
        run = vals[i] if heads[i] else max(run, vals[i])
        ref[i] = run
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@hypothesis.given(
    st.lists(st.integers(0, 7), min_size=1, max_size=200)
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_segment_rank(keys):
    keys = np.asarray(keys, np.int32)
    out = np.asarray(segops.segment_rank(jnp.asarray(keys)))
    seen: dict[int, int] = {}
    ref = np.empty_like(keys)
    for i, k in enumerate(keys):
        ref[i] = seen.get(int(k), 0)
        seen[int(k)] = ref[i] + 1
    np.testing.assert_array_equal(out, ref)


@st.composite
def queue_cases(draw):
    n = draw(st.integers(1, 100))
    ready = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    cost = draw(
        st.lists(
            st.floats(min_value=0, max_value=50, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    nseg = sum(heads)
    seeds = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=nseg, max_size=nseg,
        )
    )
    return (
        np.asarray(ready, np.float32),
        np.asarray(cost, np.float32),
        np.asarray(heads, bool),
        np.asarray(seeds, np.float32),
    )


@hypothesis.given(queue_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_queueing_scan(case):
    ready, cost, heads, seeds = case
    # Broadcast per-segment seed to rows.
    seg_id = np.cumsum(heads) - 1
    seed_rows = seeds[seg_id]
    out = np.asarray(
        segops.queueing_scan(
            jnp.asarray(ready), jnp.asarray(cost),
            jnp.asarray(heads), jnp.asarray(seed_rows),
        )
    )
    ref = np.empty_like(ready)
    busy = 0.0
    for i in range(len(ready)):
        if heads[i]:
            busy = seeds[seg_id[i]]
        busy = max(ready[i], busy) + cost[i]
        ref[i] = busy
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-2)


# ---------------------------------------------------------------------------
# Pallas routing: the seg_scan kernel vs the lax reference paths.
# ---------------------------------------------------------------------------

@hypothesis.given(seg_arrays())
@hypothesis.settings(max_examples=25, deadline=None)
def test_pallas_segmax_bit_exact(xs):
    """kernels/seg_scan ≡ segmented_prefix_max for ANY floats.

    Max is exactly associative in IEEE floats, so the Pallas kernel's
    chunked evaluation order cannot diverge from the lax scan's — the
    bit-exactness the ``queueing_scan_via_segmax`` reduction rests on.
    """
    vals, heads = xs
    ref = segops.segmented_prefix_max(jnp.asarray(vals), jnp.asarray(heads))
    out = segops._pallas_segmax(jnp.asarray(vals), jnp.asarray(heads))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@st.composite
def int_queue_cases(draw):
    """queueing_scan inputs on integer-valued f32 (< 2^24, exactly
    representable and exactly summable), so the via-segmax reduction's
    cost-sum re-association cannot round differently."""
    n = draw(st.integers(1, 100))
    ready = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    cost = draw(st.lists(st.integers(0, 50), min_size=n, max_size=n))
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    seed = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    return (
        np.asarray(ready, np.float32),
        np.asarray(cost, np.float32),
        np.asarray(heads, bool),
        np.asarray(seed, np.float32),
    )


@hypothesis.given(int_queue_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_queueing_scan_pallas_bit_exact(case):
    """use_pallas=True ≡ the lax path bit-exactly on integer-valued f32."""
    ready, cost, heads, seed = case
    args = tuple(map(jnp.asarray, (ready, cost, heads, seed)))
    ref = segops.queueing_scan(*args)
    out = segops.queueing_scan(*args, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_queueing_scan_pallas_edge_segments():
    """All-one-segment and all-heads edges, both ragged vs kernel chunk."""
    for n in (1, 7, 256, 300):
        ready = jnp.arange(n, dtype=jnp.float32) % 13
        cost = (jnp.arange(n, dtype=jnp.float32) * 7) % 5
        seed = jnp.full((n,), 3.0, jnp.float32)
        for heads in (
            jnp.zeros((n,), bool).at[0].set(True),  # one segment
            jnp.ones((n,), bool),                    # every row a head
        ):
            ref = segops.queueing_scan(ready, cost, heads, seed)
            out = segops.queueing_scan(
                ready, cost, heads, seed, use_pallas=True
            )
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Sort-plan helpers: fused/sort-free layouts vs their reference sorts.
# ---------------------------------------------------------------------------

@st.composite
def keyed_rows(draw):
    n = draw(st.integers(1, 120))
    key = draw(st.lists(st.integers(0, 6), min_size=n, max_size=n))
    t = draw(st.lists(st.integers(0, 30), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return (
        np.asarray(key, np.int32),
        np.asarray(t, np.float32),
        np.asarray(valid, bool),
    )


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_lex_sort_matches_two_pass(case):
    """lex_sort_by_segment ≡ stable sort by t then segment sort by key."""
    key, t, _ = case
    k, tt = jnp.asarray(key), jnp.asarray(t)
    ord1 = jnp.argsort(tt, stable=True)
    ord2, heads_ref, rank_ref = segops.sort_by_segment(k[ord1])
    order_ref = ord1[ord2]
    order, heads, rank = segops.lex_sort_by_segment(k, tt)
    np.testing.assert_array_equal(np.asarray(order), np.asarray(order_ref))
    np.testing.assert_array_equal(np.asarray(heads), np.asarray(heads_ref))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rank_ref))


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_presorted_plan_matches_sort(case):
    """presorted_plan ≡ make_sort_plan on a non-decreasing key."""
    key, _, _ = case
    k = jnp.sort(jnp.asarray(key))
    ref = segops.make_sort_plan(k)
    plan = segops.presorted_plan(k)
    np.testing.assert_array_equal(np.asarray(plan.order), np.asarray(ref.order))
    np.testing.assert_array_equal(np.asarray(plan.heads), np.asarray(ref.heads))
    np.testing.assert_array_equal(np.asarray(plan.rank), np.asarray(ref.rank))


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_masked_presorted_rank(case):
    """masked_presorted_rank ≡ segment_rank on valid rows (sorted key)."""
    key, _, valid = case
    k = jnp.sort(jnp.asarray(key))
    v = jnp.asarray(valid)
    g = int(jnp.max(k)) + 1
    ref = segops.segment_rank(jnp.where(v, k, jnp.int32(g)))
    out = segops.masked_presorted_rank(k, v)
    np.testing.assert_array_equal(
        np.asarray(out)[valid], np.asarray(ref)[valid]
    )


# ---------------------------------------------------------------------------
# Epoch compaction (PR 8): dense-prefix layouts vs their reference sorts.
# ---------------------------------------------------------------------------

@hypothesis.given(st.lists(st.booleans(), min_size=1, max_size=120))
@hypothesis.settings(max_examples=25, deadline=None)
def test_compact_epoch_is_order_preserving_permutation(valid):
    """Valid rows land at 0..n_valid-1 in original order; invalid rows
    pack after, also in original order; ``pos`` is a true permutation."""
    v = np.asarray(valid, bool)
    plan = segops.compact_epoch(jnp.asarray(v))
    pos = np.asarray(plan.pos)
    n_valid = int(plan.n_valid)
    assert n_valid == int(v.sum())
    assert sorted(pos.tolist()) == list(range(len(v)))  # permutation
    np.testing.assert_array_equal(
        np.sort(pos[v]), pos[v]  # order-preserving among valid rows
    )
    np.testing.assert_array_equal(np.sort(pos[~v]), pos[~v])
    assert (pos[v] < n_valid).all()
    assert (pos[~v] >= n_valid).all()


def test_compact_epoch_edge_epochs():
    """All-invalid, single-valid, and all-valid epochs."""
    n = 16
    for v in (
        np.zeros(n, bool),
        np.zeros(n, bool) | (np.arange(n) == 7),
        np.ones(n, bool),
    ):
        plan = segops.compact_epoch(jnp.asarray(v))
        pos = np.asarray(plan.pos)
        assert int(plan.n_valid) == int(v.sum())
        assert sorted(pos.tolist()) == list(range(n))


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_counting_sort_plan_matches_make_sort_plan(case):
    """counting_sort_plan ≡ make_sort_plan for a small key alphabet."""
    key, _, _ = case
    k = jnp.asarray(key)
    ref = segops.make_sort_plan(k)
    plan = segops.counting_sort_plan(k, 7)
    np.testing.assert_array_equal(
        np.asarray(plan.order), np.asarray(ref.order)
    )
    np.testing.assert_array_equal(
        np.asarray(plan.heads), np.asarray(ref.heads)
    )
    np.testing.assert_array_equal(
        np.asarray(plan.rank), np.asarray(ref.rank)
    )


@st.composite
def blocked_valids(draw):
    blocks = draw(st.integers(1, 8))
    width = draw(st.integers(1, 16))
    v = draw(
        st.lists(
            st.booleans(),
            min_size=blocks * width, max_size=blocks * width,
        )
    )
    return np.asarray(v, bool), width


@hypothesis.given(blocked_valids())
@hypothesis.settings(max_examples=25, deadline=None)
def test_block_masked_rank_and_counts(case):
    """Block forms ≡ masked_presorted_rank / segment_sum on the
    fixed-width block key ``arange(N) // block``."""
    valid, width = case
    v = jnp.asarray(valid)
    group = jnp.arange(valid.shape[0], dtype=jnp.int32) // width
    ref_rank = segops.masked_presorted_rank(group, v)
    out_rank = segops.block_masked_rank(v, width)
    np.testing.assert_array_equal(np.asarray(out_rank), np.asarray(ref_rank))
    nseg = valid.shape[0] // width
    ref_counts = np.asarray(
        jax.ops.segment_sum(
            v.astype(jnp.int32), group, num_segments=nseg
        )
    )
    out_counts = np.asarray(segops.block_counts(v, width))
    np.testing.assert_array_equal(out_counts, ref_counts)


# ---------------------------------------------------------------------------
# Compacted round-robin timing ≡ the stable-sort reference (PR 8).
# ---------------------------------------------------------------------------

@st.composite
def rr_timing_cases(draw):
    n = draw(st.integers(1, 96))
    k = draw(st.integers(1, 8))
    arrival = draw(
        st.lists(
            st.floats(
                min_value=0, max_value=1e4, width=32, allow_subnormal=False
            ),
            min_size=n, max_size=n,
        )
    )
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    busy = draw(
        st.lists(
            st.floats(
                min_value=0, max_value=1e4, width=32, allow_subnormal=False
            ),
            min_size=k, max_size=k,
        )
    )
    rr = draw(st.integers(0, k - 1))
    return (
        np.asarray(arrival, np.float32),
        np.asarray(valid, bool),
        np.asarray(busy, np.float32),
        rr, k,
    )


def _assert_rr_parity(arrival, valid, busy, rr, k):
    from repro.core import timing
    from repro.core.types import SSDConfig

    ssd = SSDConfig(n_instances=k)
    rr = jnp.int32(rr)
    inst, rr_ref = timing.assign_rr(rr, jnp.asarray(valid), k)
    comp_ref, busy_ref = timing.aggregated_batch_times(
        jnp.asarray(busy), jnp.asarray(arrival), inst, jnp.asarray(valid),
        ssd,
    )
    comp, new_busy, rr_out = timing.compact_rr_batch_times(
        jnp.asarray(busy), jnp.asarray(arrival), rr, jnp.asarray(valid),
        ssd,
    )
    np.testing.assert_array_equal(np.asarray(comp), np.asarray(comp_ref))
    np.testing.assert_array_equal(
        np.asarray(new_busy), np.asarray(busy_ref)
    )
    assert int(rr_out) == int(rr_ref)


@hypothesis.given(rr_timing_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_compact_rr_batch_times_bit_exact(case):
    """compact_rr_batch_times ≡ aggregated_batch_times + assign_rr.

    Bit-exact for ANY float arrivals/cursors: both paths feed the same
    instance-major layout through the shared ``_sorted_batch_core``
    float expression tree, so only the (integer) permutation
    construction differs.
    """
    _assert_rr_parity(*case)


def test_compact_rr_batch_times_edge_epochs():
    """All-invalid, single-valid, and all-valid epochs, rr offsets."""
    n, k = 24, 4
    arrival = (np.arange(n, dtype=np.float32) * 3.5) % 17
    busy = np.asarray([5.0, 0.0, 12.25, 2.0], np.float32)
    for rr in (0, 3):
        for valid in (
            np.zeros(n, bool),
            np.zeros(n, bool) | (np.arange(n) == 11),
            np.ones(n, bool),
        ):
            _assert_rr_parity(arrival, valid, busy, rr, k)


# ---------------------------------------------------------------------------
# Fused Pallas stage kernels (PR 8) vs sequential python references.
# ---------------------------------------------------------------------------

@st.composite
def reap_cases(draw):
    q = draw(st.integers(1, 4))
    depth = draw(st.integers(1, 8))
    n = draw(st.integers(1, 48))
    key = draw(st.lists(st.integers(0, q - 1), min_size=n, max_size=n))
    done = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    req = draw(st.lists(st.integers(0, 1 << 20), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    tail = draw(st.lists(st.integers(0, 1 << 20), min_size=q, max_size=q))
    return q, depth, (
        np.asarray(key, np.int32), np.asarray(done, np.float32),
        np.asarray(req, np.int32), np.asarray(valid, bool),
        np.asarray(tail, np.int32),
    )


@hypothesis.given(reap_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_fused_reap_matches_sequential_reference(case):
    """kernels/fused_reap ≡ the per-row posting loop for ANY inputs
    (pure integer bookkeeping + data movement; no float arithmetic)."""
    from repro.kernels import ops as kops

    q, depth, (key, done, req, valid, tail) = case
    rng = np.random.default_rng(0)
    dt0 = rng.uniform(0, 9, (q, depth)).astype(np.float32)
    vt0 = rng.uniform(0, 9, (q, depth)).astype(np.float32)
    rid0 = rng.integers(0, 99, (q, depth)).astype(np.int32)

    ref_dt, ref_vt, ref_rid = dt0.copy(), vt0.copy(), rid0.copy()
    counts = np.zeros(q, np.int32)
    for i in range(len(key)):
        if valid[i]:
            c = key[i]
            pos = (tail[c] + counts[c]) % depth
            ref_dt[c, pos] = done[i]
            ref_vt[c, pos] = done[i]
            ref_rid[c, pos] = req[i]
            counts[c] += 1

    dt, vt, rid, cnt = kops.fused_reap(
        jnp.asarray(dt0), jnp.asarray(vt0), jnp.asarray(rid0),
        jnp.asarray(tail), jnp.asarray(key), jnp.asarray(done),
        jnp.asarray(req), jnp.asarray(valid),
    )
    np.testing.assert_array_equal(np.asarray(dt), ref_dt)
    np.testing.assert_array_equal(np.asarray(vt), ref_vt)
    np.testing.assert_array_equal(np.asarray(rid), ref_rid)
    np.testing.assert_array_equal(np.asarray(cnt), counts)


@st.composite
def die_cases(draw):
    n = draw(st.integers(1, 48))
    k = draw(st.integers(1, 6))
    ready = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    cost = draw(st.lists(st.integers(1, 50), min_size=n, max_size=n))
    chip = draw(st.lists(st.integers(0, k - 1), min_size=n, max_size=n))
    event = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    cur = draw(st.lists(st.integers(0, 1000), min_size=k, max_size=k))
    return (
        np.asarray(ready, np.float32), np.asarray(cost, np.float32),
        np.asarray(chip, np.int32), np.asarray(event, bool),
        np.asarray(cur, np.float32),
    )


@hypothesis.given(die_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_die_contention_matches_sequential_reference(case):
    """kernels/die_contention ≡ the sequential per-die fold.

    Integer-valued f32 inputs (the kernel's bit-exactness contract —
    same as ``use_pallas_segscan``; full-run engine parity on such a
    platform is pinned in tests/test_emulator_speed.py).
    """
    from repro.kernels import ops as kops

    ready, cost, chip, event, cur0 = case
    cur = cur0.copy()
    ref_busy = np.zeros_like(ready)
    for i in range(len(ready)):
        if event[i]:
            c = chip[i]
            b = max(cur[c], ready[i]) + cost[i]
            ref_busy[i] = b
            cur[c] = b

    busy, new_cur = kops.die_contention(
        jnp.asarray(ready), jnp.asarray(cost), jnp.asarray(chip),
        jnp.asarray(event), jnp.asarray(cur0),
    )
    np.testing.assert_array_equal(np.asarray(busy), ref_busy)
    np.testing.assert_array_equal(np.asarray(new_cur), cur)
