"""Property tests for the segmented-scan primitives."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep"
)
st = pytest.importorskip("hypothesis.strategies")
import jax.numpy as jnp
import numpy as np

from repro.core import segops


@st.composite
def seg_arrays(draw):
    n = draw(st.integers(1, 128))
    vals = draw(
        st.lists(
            st.floats(min_value=-1e4, max_value=1e4, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    return np.asarray(vals, np.float32), np.asarray(heads, bool)


@hypothesis.given(seg_arrays())
@hypothesis.settings(max_examples=25, deadline=None)
def test_segmented_prefix_max(xs):
    vals, heads = xs
    out = np.asarray(
        segops.segmented_prefix_max(jnp.asarray(vals), jnp.asarray(heads))
    )
    ref = np.empty_like(vals)
    run = -np.inf
    for i in range(len(vals)):
        run = vals[i] if heads[i] else max(run, vals[i])
        ref[i] = run
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@hypothesis.given(
    st.lists(st.integers(0, 7), min_size=1, max_size=200)
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_segment_rank(keys):
    keys = np.asarray(keys, np.int32)
    out = np.asarray(segops.segment_rank(jnp.asarray(keys)))
    seen: dict[int, int] = {}
    ref = np.empty_like(keys)
    for i, k in enumerate(keys):
        ref[i] = seen.get(int(k), 0)
        seen[int(k)] = ref[i] + 1
    np.testing.assert_array_equal(out, ref)


@st.composite
def queue_cases(draw):
    n = draw(st.integers(1, 100))
    ready = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    cost = draw(
        st.lists(
            st.floats(min_value=0, max_value=50, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    nseg = sum(heads)
    seeds = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=nseg, max_size=nseg,
        )
    )
    return (
        np.asarray(ready, np.float32),
        np.asarray(cost, np.float32),
        np.asarray(heads, bool),
        np.asarray(seeds, np.float32),
    )


@hypothesis.given(queue_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_queueing_scan(case):
    ready, cost, heads, seeds = case
    # Broadcast per-segment seed to rows.
    seg_id = np.cumsum(heads) - 1
    seed_rows = seeds[seg_id]
    out = np.asarray(
        segops.queueing_scan(
            jnp.asarray(ready), jnp.asarray(cost),
            jnp.asarray(heads), jnp.asarray(seed_rows),
        )
    )
    ref = np.empty_like(ready)
    busy = 0.0
    for i in range(len(ready)):
        if heads[i]:
            busy = seeds[seg_id[i]]
        busy = max(ready[i], busy) + cost[i]
        ref[i] = busy
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-2)


# ---------------------------------------------------------------------------
# Pallas routing: the seg_scan kernel vs the lax reference paths.
# ---------------------------------------------------------------------------

@hypothesis.given(seg_arrays())
@hypothesis.settings(max_examples=25, deadline=None)
def test_pallas_segmax_bit_exact(xs):
    """kernels/seg_scan ≡ segmented_prefix_max for ANY floats.

    Max is exactly associative in IEEE floats, so the Pallas kernel's
    chunked evaluation order cannot diverge from the lax scan's — the
    bit-exactness the ``queueing_scan_via_segmax`` reduction rests on.
    """
    vals, heads = xs
    ref = segops.segmented_prefix_max(jnp.asarray(vals), jnp.asarray(heads))
    out = segops._pallas_segmax(jnp.asarray(vals), jnp.asarray(heads))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@st.composite
def int_queue_cases(draw):
    """queueing_scan inputs on integer-valued f32 (< 2^24, exactly
    representable and exactly summable), so the via-segmax reduction's
    cost-sum re-association cannot round differently."""
    n = draw(st.integers(1, 100))
    ready = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    cost = draw(st.lists(st.integers(0, 50), min_size=n, max_size=n))
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    seed = draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    return (
        np.asarray(ready, np.float32),
        np.asarray(cost, np.float32),
        np.asarray(heads, bool),
        np.asarray(seed, np.float32),
    )


@hypothesis.given(int_queue_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_queueing_scan_pallas_bit_exact(case):
    """use_pallas=True ≡ the lax path bit-exactly on integer-valued f32."""
    ready, cost, heads, seed = case
    args = tuple(map(jnp.asarray, (ready, cost, heads, seed)))
    ref = segops.queueing_scan(*args)
    out = segops.queueing_scan(*args, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_queueing_scan_pallas_edge_segments():
    """All-one-segment and all-heads edges, both ragged vs kernel chunk."""
    for n in (1, 7, 256, 300):
        ready = jnp.arange(n, dtype=jnp.float32) % 13
        cost = (jnp.arange(n, dtype=jnp.float32) * 7) % 5
        seed = jnp.full((n,), 3.0, jnp.float32)
        for heads in (
            jnp.zeros((n,), bool).at[0].set(True),  # one segment
            jnp.ones((n,), bool),                    # every row a head
        ):
            ref = segops.queueing_scan(ready, cost, heads, seed)
            out = segops.queueing_scan(
                ready, cost, heads, seed, use_pallas=True
            )
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Sort-plan helpers: fused/sort-free layouts vs their reference sorts.
# ---------------------------------------------------------------------------

@st.composite
def keyed_rows(draw):
    n = draw(st.integers(1, 120))
    key = draw(st.lists(st.integers(0, 6), min_size=n, max_size=n))
    t = draw(st.lists(st.integers(0, 30), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return (
        np.asarray(key, np.int32),
        np.asarray(t, np.float32),
        np.asarray(valid, bool),
    )


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_lex_sort_matches_two_pass(case):
    """lex_sort_by_segment ≡ stable sort by t then segment sort by key."""
    key, t, _ = case
    k, tt = jnp.asarray(key), jnp.asarray(t)
    ord1 = jnp.argsort(tt, stable=True)
    ord2, heads_ref, rank_ref = segops.sort_by_segment(k[ord1])
    order_ref = ord1[ord2]
    order, heads, rank = segops.lex_sort_by_segment(k, tt)
    np.testing.assert_array_equal(np.asarray(order), np.asarray(order_ref))
    np.testing.assert_array_equal(np.asarray(heads), np.asarray(heads_ref))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rank_ref))


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_presorted_plan_matches_sort(case):
    """presorted_plan ≡ make_sort_plan on a non-decreasing key."""
    key, _, _ = case
    k = jnp.sort(jnp.asarray(key))
    ref = segops.make_sort_plan(k)
    plan = segops.presorted_plan(k)
    np.testing.assert_array_equal(np.asarray(plan.order), np.asarray(ref.order))
    np.testing.assert_array_equal(np.asarray(plan.heads), np.asarray(ref.heads))
    np.testing.assert_array_equal(np.asarray(plan.rank), np.asarray(ref.rank))


@hypothesis.given(keyed_rows())
@hypothesis.settings(max_examples=25, deadline=None)
def test_masked_presorted_rank(case):
    """masked_presorted_rank ≡ segment_rank on valid rows (sorted key)."""
    key, _, valid = case
    k = jnp.sort(jnp.asarray(key))
    v = jnp.asarray(valid)
    g = int(jnp.max(k)) + 1
    ref = segops.segment_rank(jnp.where(v, k, jnp.int32(g)))
    out = segops.masked_presorted_rank(k, v)
    np.testing.assert_array_equal(
        np.asarray(out)[valid], np.asarray(ref)[valid]
    )
