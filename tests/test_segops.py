"""Property tests for the segmented-scan primitives."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep"
)
st = pytest.importorskip("hypothesis.strategies")
import jax.numpy as jnp
import numpy as np

from repro.core import segops


@st.composite
def seg_arrays(draw):
    n = draw(st.integers(1, 128))
    vals = draw(
        st.lists(
            st.floats(min_value=-1e4, max_value=1e4, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    return np.asarray(vals, np.float32), np.asarray(heads, bool)


@hypothesis.given(seg_arrays())
@hypothesis.settings(max_examples=25, deadline=None)
def test_segmented_prefix_max(xs):
    vals, heads = xs
    out = np.asarray(
        segops.segmented_prefix_max(jnp.asarray(vals), jnp.asarray(heads))
    )
    ref = np.empty_like(vals)
    run = -np.inf
    for i in range(len(vals)):
        run = vals[i] if heads[i] else max(run, vals[i])
        ref[i] = run
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@hypothesis.given(
    st.lists(st.integers(0, 7), min_size=1, max_size=200)
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_segment_rank(keys):
    keys = np.asarray(keys, np.int32)
    out = np.asarray(segops.segment_rank(jnp.asarray(keys)))
    seen: dict[int, int] = {}
    ref = np.empty_like(keys)
    for i, k in enumerate(keys):
        ref[i] = seen.get(int(k), 0)
        seen[int(k)] = ref[i] + 1
    np.testing.assert_array_equal(out, ref)


@st.composite
def queue_cases(draw):
    n = draw(st.integers(1, 100))
    ready = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    cost = draw(
        st.lists(
            st.floats(min_value=0, max_value=50, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    heads = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    heads[0] = True
    nseg = sum(heads)
    seeds = draw(
        st.lists(
            st.floats(min_value=0, max_value=1e3, width=32, allow_subnormal=False),
            min_size=nseg, max_size=nseg,
        )
    )
    return (
        np.asarray(ready, np.float32),
        np.asarray(cost, np.float32),
        np.asarray(heads, bool),
        np.asarray(seeds, np.float32),
    )


@hypothesis.given(queue_cases())
@hypothesis.settings(max_examples=25, deadline=None)
def test_queueing_scan(case):
    ready, cost, heads, seeds = case
    # Broadcast per-segment seed to rows.
    seg_id = np.cumsum(heads) - 1
    seed_rows = seeds[seg_id]
    out = np.asarray(
        segops.queueing_scan(
            jnp.asarray(ready), jnp.asarray(cost),
            jnp.asarray(heads), jnp.asarray(seed_rows),
        )
    )
    ref = np.empty_like(ready)
    busy = 0.0
    for i in range(len(ready)):
        if heads[i]:
            busy = seeds[seg_id[i]]
        busy = max(ready[i], busy) + cost[i]
        ref[i] = busy
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-2)
