"""Serving loop: generation + virtual-time KV-tier accounting."""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.types import EngineConfig, SSDConfig
from repro.models import transformer
from repro.serving import kv_tier
from repro.serving import loop as serve_loop

ARCH = "yi-34b"


def _setup(batch=2, prompt=16):
    cfg = configs.get_config(ARCH, smoke=True)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt), 0, cfg.vocab
    )
    return cfg, params, tokens


def test_generate_shapes_and_determinism():
    cfg, params, tokens = _setup()
    scfg = serve_loop.ServeConfig(batch=2, prompt_len=16, gen_tokens=4)
    out1 = serve_loop.generate(cfg, params, tokens, scfg)
    out2 = serve_loop.generate(cfg, params, tokens, scfg)
    assert out1["tokens"].shape == (2, 4)
    assert jnp.array_equal(out1["tokens"], out2["tokens"])
    assert out1["wall_s"] >= 0.0


def test_serve_with_kv_tier_stats_and_device_independence():
    """Generated tokens are device-independent (functional path);
    virtual tokens/s is not, and the tier's round-trip check holds."""
    cfg, params, tokens = _setup()
    scfg = serve_loop.ServeConfig(
        batch=2, prompt_len=16, gen_tokens=4,
        tier=kv_tier.KVTierConfig(page_tokens=4, hot_window=8,
                                  gpu_step_us=20.0),
    )
    ecfg = EngineConfig(num_units=4, fetch_width=64)
    slow = SSDConfig(t_max_iops=2e5, l_min_us=20.0, n_instances=32,
                     num_blocks=1 << 14)
    fast = slow.replace(t_max_iops=4e6)
    out_slow = serve_loop.serve_with_kv_tier(
        cfg, params, tokens, scfg, slow, ecfg
    )
    out_fast = serve_loop.serve_with_kv_tier(
        cfg, params, tokens, scfg, fast, ecfg
    )
    assert jnp.array_equal(out_slow["tokens"], out_fast["tokens"])
    assert out_fast["tokens_per_s"] > out_slow["tokens_per_s"]
    assert out_slow["data_check_max_abs"] == 0.0
    assert out_fast["data_check_max_abs"] == 0.0
    assert out_slow["avg_step_us"] >= out_slow["avg_storage_us"]
