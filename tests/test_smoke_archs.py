"""Per-arch smoke tests: reduced same-family configs, one forward/train
step + one prefill/decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import modality, transformer

B, S = 2, 64


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    embeds = None
    mrope = None
    if cfg.modality == "audio":
        embeds = modality.audio_frame_embeddings(key, cfg, B, S)
    elif cfg.modality == "vision":
        embeds, mrope = modality.vision_patch_embeddings(key, cfg, B, S)
    return tokens, labels, embeds, mrope


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_loss(arch):
    cfg = configs.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(key, cfg)
    tokens, labels, embeds, mrope = _inputs(cfg, jax.random.PRNGKey(1))

    h, aux = jax.jit(
        lambda p, t, e: transformer.forward(
            p, cfg, tokens=None if e is not None else t, embeds=e,
            mrope_positions=mrope,
        )
    )(params, tokens, embeds)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all(), arch
    loss = jax.jit(
        lambda p: transformer.loss_fn(
            p, cfg, None if embeds is not None else tokens, labels,
            embeds=embeds, mrope_positions=mrope,
        )
    )(params)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step(arch):
    """One SGD step: grads exist, are finite, and change the params."""
    cfg = configs.get_config(arch, smoke=True)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tokens, labels, embeds, mrope = _inputs(cfg, jax.random.PRNGKey(1))

    def loss(p):
        return transformer.loss_fn(
            p, cfg, None if embeds is not None else tokens, labels,
            embeds=embeds, mrope_positions=mrope,
        )

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_decode(arch):
    """Prefill a prompt, decode 3 tokens; logits finite and shaped."""
    cfg = configs.get_config(arch, smoke=True)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tokens, _, embeds, mrope = _inputs(cfg, jax.random.PRNGKey(1))
    cache_len = S + 4

    logits, caches = jax.jit(
        lambda p, t, e: transformer.prefill(
            p, cfg, tokens=None if e is not None else t, embeds=e,
            cache_len=cache_len, mrope_positions=mrope,
        )
    )(params, tokens, embeds)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    step = jax.jit(
        lambda p, tok, c, pos: transformer.decode_step(p, cfg, tok, c, pos)
    )
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(3):
        pos = jnp.int32(S + i)
        logits, caches = step(params, tok, caches, pos)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), (arch, i)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Teacher-forced decode == forward logits (KV-cache correctness),
    checked on a dense arch."""
    cfg = configs.get_config("yi-34b", smoke=True)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 16), 0, cfg.vocab)

    h, _ = transformer.forward(params, cfg, tokens=tokens)
    full_logits = transformer.logits_fn(params, cfg, h)    # (B, 16, V)

    prompt = tokens[:, :8]
    logits, caches = transformer.prefill(
        params, cfg, tokens=prompt, cache_len=16
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 7]), rtol=2e-4,
        atol=2e-4,
    )
    for i in range(8, 16):
        logits, caches = transformer.decode_step(
            params, cfg, tokens[:, i], caches, jnp.int32(i)
        )
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]), rtol=2e-4,
            atol=2e-4,
        )


def test_param_counts_are_plausible():
    """Analytic param counts should be in the advertised ballpark."""
    expect = {
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "qwen2-moe-a2.7b": (12e9, 18e9),
        "starcoder2-3b": (2.5e9, 3.5e9),
        "command-r-plus-104b": (85e9, 115e9),
        "yi-34b": (30e9, 38e9),
        "gemma2-27b": (22e9, 30e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "musicgen-large": (1.5e9, 2.8e9),
        "qwen2-vl-72b": (62e9, 80e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")
