"""Substrate tests: optimizer, data, checkpoint/restart, compression,
launcher policy, serving KV tier, storage client."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, configs
from repro.core.client import ClientState, StorageClient
from repro.core.types import EngineConfig, PlatformModel, SSDConfig
from repro.distributed import compression
from repro.launch.launcher import Supervisor, SupervisorConfig
from repro.models import transformer
from repro.serving import kv_tier
from repro.train import data as data_lib
from repro.train import loop as train_loop
from repro.train import optimizer as opt_lib


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synth_batch_deterministic():
    a = data_lib.synth_batch(7, 4, 16, 1000)
    b = data_lib.synth_batch(7, 4, 16, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = data_lib.synth_batch(8, 4, 16, 1000)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_orders_batches():
    pf = data_lib.Prefetcher(2, 8, 100, start_idx=3)
    it = iter(pf)
    idxs = [next(it)[0] for _ in range(4)]
    pf.close()
    assert idxs == [3, 4, 5, 6]


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    cfg = opt_lib.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                              weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_lib.init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt_lib.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.05


def test_grad_clip_metric():
    cfg = opt_lib.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt_lib.init_opt_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = opt_lib.apply_updates(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    checkpoint.save(str(tmp_path), 5, tree)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    loaded, manifest = checkpoint.load(str(tmp_path), template)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(loaded["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_atomicity_and_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        checkpoint.save(str(tmp_path), s, tree)
    # A stale tmp dir must not be picked up as latest.
    os.makedirs(tmp_path / "step_00000099.tmp", exist_ok=True)
    assert checkpoint.latest_step(str(tmp_path)) == 4
    checkpoint.gc_old(str(tmp_path), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_reshard_on_load(tmp_path):
    """Load onto a different sharding (elastic mesh change analogue)."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    checkpoint.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = {"w": NamedSharding(mesh, P("data"))}
    loaded, _ = checkpoint.load(
        str(tmp_path), jax.tree.map(jnp.zeros_like, tree),
        shardings=shardings,
    )
    np.testing.assert_array_equal(np.asarray(loaded["w"]),
                                  np.arange(8, dtype=np.float32))
    assert loaded["w"].sharding == shardings["w"]


# ---------------------------------------------------------------------------
# train loop end-to-end (+ failure injection / restart)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return configs.get_config("yi-34b", smoke=True).replace(
        n_layers=1, loss_chunk=32,
    )


def test_train_loop_runs_and_checkpoints(tmp_path):
    cfg = _tiny_cfg()
    tcfg = train_loop.TrainConfig(
        batch=2, seq=32, steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
    )
    res = train_loop.train(cfg, tcfg, resume=False)
    assert res.step == 6
    assert len(res.losses) == 6
    assert all(np.isfinite(l) for l in res.losses)
    assert checkpoint.latest_step(str(tmp_path)) == 6


def test_train_loop_failure_restart(tmp_path):
    cfg = _tiny_cfg()
    tcfg = train_loop.TrainConfig(
        batch=2, seq=32, steps=8, ckpt_every=2, ckpt_dir=str(tmp_path),
    )
    res = train_loop.train(cfg, tcfg, resume=False, fail_at={5})
    assert res.restarts == 1
    assert res.step == 8
    # Restart resumed from step-4 checkpoint: steps 5..8 re-run => 4 + 8-4 = 8.
    assert checkpoint.latest_step(str(tmp_path)) == 8


def test_grad_accum_equivalence(tmp_path):
    """grad_accum=2 over a doubled batch == single large-batch step."""
    cfg = _tiny_cfg()
    t1 = train_loop.TrainConfig(batch=4, seq=32, steps=1, grad_accum=1,
                                ckpt_dir=str(tmp_path / "a"))
    t2 = train_loop.TrainConfig(batch=4, seq=32, steps=1, grad_accum=2,
                                ckpt_dir=str(tmp_path / "b"))
    r1 = train_loop.train(cfg, t1, resume=False)
    r2 = train_loop.train(cfg, t2, resume=False)
    assert r1.losses[0] == pytest.approx(r2.losses[0], rel=1e-4)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    """Accumulated EF residual keeps the long-run mean unbiased."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    res = jnp.zeros((1024,))
    total = jnp.zeros((1024,))
    for _ in range(50):
        deq, res = compression.compress_leaf(g, res)
        total = total + deq
    np.testing.assert_allclose(
        np.asarray(total / 50), np.asarray(g), atol=2e-2
    )


def test_compression_ratio():
    params = {"w": jnp.zeros((4096, 64))}
    wire = compression.compressed_bytes(params)
    raw = 4096 * 64 * 4
    assert wire < raw / 3.5  # ~4x compression incl. scales


# ---------------------------------------------------------------------------
# launcher policy
# ---------------------------------------------------------------------------

def test_supervisor_detects_dead_and_restarts():
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout_s=10))
    now = 1000.0
    for w in range(4):
        sup.heartbeat(w, now)
    assert sup.handle_failures(now + 5)["action"] == "none"
    sup.heartbeat(0, now + 20)
    sup.heartbeat(1, now + 20)
    sup.heartbeat(2, now + 20)
    # worker 3 silent for >10s
    act = sup.handle_failures(now + 20)
    assert act["action"] == "elastic_downsize"
    assert act["new_data_parallel"] == 2
    assert act["reshard"] is True


def test_supervisor_full_restart_when_capacity_returns():
    sup = Supervisor(2, SupervisorConfig(heartbeat_timeout_s=10))
    sup.heartbeat(0, 100.0)
    sup.heartbeat(1, 100.0)
    act = sup.handle_failures(100.0 + 20)  # both dead -> abort (no capacity)
    assert act["action"] == "abort"


def test_supervisor_straggler_backup_dispatch():
    sup = Supervisor(4, SupervisorConfig(straggler_factor=1.5,
                                         straggler_patience=2))
    acts = []
    for step in range(3):
        for w in range(4):
            sup.report_step_time(w, 1.0 if w != 2 else 2.5)
        acts.extend(sup.straggler_actions())
    assert any(a["worker"] == 2 for a in acts)


# ---------------------------------------------------------------------------
# storage client + KV tier
# ---------------------------------------------------------------------------

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 14)


def test_storage_client_latency_floor_and_data():
    ecfg = EngineConfig(num_units=4, fetch_width=64)
    client = StorageClient(SSD, ecfg)
    state = ClientState.init(SSD, 4)
    flash = jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, 8)
    )
    lba = jnp.asarray([3, 999, 4095], jnp.int32)
    state, data, done = client.read(state, flash, lba, jnp.float32(0))
    np.testing.assert_array_equal(np.asarray(data[:, 0]), [3, 999, 4095])
    lat = np.asarray(done)
    assert (lat >= 50.0 - 1e-3).all()
    assert (lat <= 60.0).all()  # floor + small overheads at light load


def test_storage_client_throughput_cap():
    ecfg = EngineConfig(num_units=8, fetch_width=64)
    client = StorageClient(SSD, ecfg)
    state = ClientState.init(SSD, 8)
    flash = jnp.ones((SSD.num_blocks, 8))
    n = 16384
    lba = jnp.arange(n, dtype=jnp.int32) % SSD.num_blocks
    state, _, done = client.read(state, flash, lba, jnp.float32(0))
    span = float(jnp.max(done)) * 1e-6
    iops = n / span
    assert iops == pytest.approx(2.47e6, rel=0.1)


def test_kv_tier_tokens_scale_with_iops():
    """More device IOPS ⇒ higher decode tokens/s (paper's end-to-end story)."""
    cfg = configs.get_config("yi-34b", smoke=True)
    tier = kv_tier.KVTierConfig(page_tokens=16, hot_window=64,
                                gpu_step_us=100.0)
    ecfg = EngineConfig(num_units=8, fetch_width=64)
    slow = SSD.replace(t_max_iops=1e5, num_blocks=1 << 14)
    fast = SSD.replace(t_max_iops=4e6, num_blocks=1 << 14)
    r_slow = kv_tier.decode_tokens_per_s(
        cfg, tier, slow, ecfg, batch=4, start_len=512, n_steps=8,
    )
    r_fast = kv_tier.decode_tokens_per_s(
        cfg, tier, fast, ecfg, batch=4, start_len=512, n_steps=8,
    )
    assert r_fast["tokens_per_s"] > 2 * r_slow["tokens_per_s"]
    assert r_slow["avg_storage_us"] > r_fast["avg_storage_us"]
