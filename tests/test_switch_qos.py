"""Shared-switch incast and per-tenant QoS tests (fabric layer, PR 5).

Contracts under test:
  * MTU stragglers pay their own wire-transaction setup (a frame that
    misses its batch's doorbell cannot ride it for free);
  * the shared switch serializes every lane at its fair share of the
    aggregate roof, delivered throughput never exceeds it
    (conservation), and an unconstrained switch is an exact no-op;
  * weighted-fair QoS: shares sum to 1, a tenant's share is monotone
    in its weight, the weighted arbiter un-starves a latency tenant's
    reads from behind a bulk-write tenant, and weights on a zero-cost
    wire are bit-exact neutral (engine and client, including
    ``read_replicated`` and writes);
  * replica routing balances on *local* arrays (device-side busy
    signal) — including around a drive that is already busy from an
    earlier call, which the wire-cursor-only signal was blind to.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.client import StorageClient
from repro.core.fabric import fabric_hop, switch_hop
from repro.core.types import (
    EngineConfig,
    FabricConfig,
    SSDConfig,
    WorkloadConfig,
)
from repro import workloads

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)
FRAME = FabricConfig().cqe_bytes + SSD.block_bytes  # RX bytes per read


def _flash_store(words=8):
    return jnp.arange(SSD.num_blocks, dtype=jnp.float32)[:, None] * jnp.ones(
        (1, words)
    )


# ---------------------------------------------------------------------------
# Satellite: post-flush MTU stragglers pay wire-transaction setup.
# ---------------------------------------------------------------------------

def test_mtu_straggler_pays_wire_txn():
    """Three frames flush at the timeout; the fourth becomes ready long
    after the doorbell rang, ships as its own transaction, and pays
    ``wire_txn_us`` — it no longer rides the flushed batch for free."""
    t = jnp.asarray([0.0, 0.0, 0.0, 100.0], jnp.float32)
    ones = jnp.ones((4,), bool)
    nbytes = jnp.full((4,), 64.0)
    fab = FabricConfig(remote=True, mtu_batch=4, mtu_timeout_us=1.0,
                       wire_txn_us=5.0)
    _, out = fabric_hop(
        jnp.float32(0), t, nbytes, ones, fab, float("inf")
    )
    # Batch head pays setup at the flush: frames 0-2 land at 1 + 5.
    np.testing.assert_allclose(np.asarray(out)[:3], 6.0, rtol=1e-6)
    # The straggler lands at its own ready time plus its own setup.
    assert float(out[3]) == pytest.approx(105.0, rel=1e-6)
    # With zero setup cost the straggler is unchanged (neutrality).
    _, out0 = fabric_hop(
        jnp.float32(0), t, nbytes, ones,
        fab.replace(wire_txn_us=0.0), float("inf"),
    )
    assert float(out0[3]) == pytest.approx(100.0, rel=1e-6)


# ---------------------------------------------------------------------------
# Shared switch: serialization at the fair share, conservation, no-op.
# ---------------------------------------------------------------------------

def test_switch_hop_serializes_at_fair_share():
    """16 frames of 500 B through a 1000 B/us switch split 4 ways: each
    lane's share is 250 B/us, so frames stream out 2 us apart."""
    n = 16
    t = jnp.zeros((n,), jnp.float32)
    fab = FabricConfig(remote=True, switch_bytes_per_us=1000.0,
                       switch_fanin=4)
    busy, out = switch_hop(
        jnp.float32(0), t, jnp.full((n,), 500.0), jnp.ones((n,), bool), fab
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(out)), (np.arange(n) + 1) * 2.0, rtol=1e-5
    )
    assert float(jnp.max(busy)) == pytest.approx(n * 2.0, rel=1e-5)


def test_switch_config_validation_and_neutrality():
    with pytest.raises(ValueError, match="switch_bytes_per_us"):
        FabricConfig(switch_bytes_per_us=0.0)
    with pytest.raises(ValueError, match="switch_fanin"):
        FabricConfig(switch_fanin=0)
    with pytest.raises(ValueError, match="qos_weights"):
        FabricConfig(qos_weights=(1.0, 0.0))
    assert not FabricConfig(remote=True, switch_bytes_per_us=1e3).neutral
    assert not FabricConfig(switch_bytes_per_us=1e3).switched  # local
    assert FabricConfig(remote=True, qos_weights=(3.0, 1.0)).neutral
    assert FabricConfig(
        remote=True, switch_bytes_per_us=4e3, switch_fanin=4
    ).switch_share_bytes_per_us == pytest.approx(1e3)


def test_engine_zero_cost_switch_is_bit_exact():
    """A remote array behind an unconstrained switch (the default)
    reproduces the local pipeline bit-exactly — the acceptance bar."""
    wl = WorkloadConfig(io_depth=32)
    local = engine.simulate(CFG, SSD, wl, rounds=16)
    remote = engine.simulate(
        CFG.replace(fabric=FabricConfig(remote=True)), SSD, wl, rounds=16
    )
    for got, want in [
        (remote.metrics.lat_hist, local.metrics.lat_hist),
        (remote.metrics.sum_e2e, local.metrics.sum_e2e),
        (remote.metrics.tenant_completed, local.metrics.tenant_completed),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert float(jnp.max(remote.device.fabric.switch_rx)) == 0.0


def test_switch_conservation_never_exceeds_roof():
    """Fast drives behind a narrow switch: per-lane delivered bytes stay
    under the lane's fair share and the aggregate stays under the
    switch roof (the fig25 regime)."""
    ssd = SSDConfig(t_max_iops=1e7, l_min_us=30.0, n_instances=256,
                    num_blocks=1 << 12)
    fab = FabricConfig(remote=True, switch_bytes_per_us=2000.0,
                       switch_fanin=2)
    out = engine.simulate(
        CFG.replace(fabric=fab), ssd, WorkloadConfig(io_depth=256),
        rounds=16, num_devices=2,
    )
    span = np.asarray(
        out.metrics.last_completion - out.metrics.first_submit
    )
    rate = np.asarray(out.metrics.completed) / span  # per-drive req/us
    share = fab.switch_share_bytes_per_us
    assert (rate * FRAME <= share * 1.1).all()
    assert float(np.sum(rate)) * FRAME <= fab.switch_bytes_per_us * 1.1
    # And the switch really is the binding stage here.
    assert float(np.sum(rate)) * FRAME >= fab.switch_bytes_per_us * 0.5


# ---------------------------------------------------------------------------
# Per-tenant QoS: neutrality, shares, starvation relief.
# ---------------------------------------------------------------------------

def test_qos_weights_on_free_wire_are_bit_exact():
    """Weights reorder only frames that cost nothing on a zero-cost
    wire, so a weighted remote run reproduces the local pipeline
    bit-exactly — including the per-tenant metrics."""
    wl = workloads.MultiTenant(io_depth=16, tenant_read_frac=(1.0, 0.3))
    local = engine.simulate(CFG, SSD, wl, rounds=16)
    weighted = engine.simulate(
        CFG.replace(
            fabric=FabricConfig(remote=True, qos_weights=(3.0, 1.0))
        ),
        SSD, wl, rounds=16,
    )
    for got, want in [
        (weighted.metrics.lat_hist, local.metrics.lat_hist),
        (weighted.metrics.tenant_completed, local.metrics.tenant_completed),
        (weighted.metrics.tenant_sum_e2e, local.metrics.tenant_sum_e2e),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qos_share_sums_to_one_and_is_monotone_in_weight():
    """Two equal read tenants on an RX-bound link: equal weights split
    the link evenly; growing tenant 0's weight monotonically grows its
    achieved completion share."""
    shares = []
    for weights in [(1.0, 1.0), (3.0, 1.0), (7.0, 1.0)]:
        fab = FabricConfig(remote=True, rx_bytes_per_us=1000.0,
                           tx_bytes_per_us=8000.0, qos_weights=weights)
        wl = workloads.MultiTenant(io_depth=32,
                                   tenant_read_frac=(1.0, 1.0))
        out = engine.simulate(CFG.replace(fabric=fab), SSD, wl, rounds=64)
        share = np.asarray(out.metrics.tenant_share())
        assert float(np.sum(share)) == pytest.approx(1.0, abs=1e-5)
        shares.append(float(share[0]))
    assert shares[0] == pytest.approx(0.5, abs=0.03)
    assert shares[0] < shares[1] < shares[2]
    assert shares[1] > 0.6   # weight 3/4 pulls well past an even split
    assert shares[2] > 0.7


def test_qos_unstarves_reads_behind_bulk_writes():
    """TX-bound link, read tenant vs bulk-write tenant: under FIFO the
    64 B read SQEs queue behind 576 B write frames (reads land near
    write latency); a read-weighted arbiter restores the reads to near
    their uncontended floor while the bulk tenant keeps making
    progress."""
    wl = workloads.MultiTenant(io_depth=32, tenant_read_frac=(1.0, 0.0))
    lat = {}
    for name, weights in [("fifo", ()), ("wfq", (4.0, 1.0))]:
        fab = FabricConfig(remote=True, tx_bytes_per_us=400.0,
                           rx_bytes_per_us=8000.0, qos_weights=weights)
        out = engine.simulate(CFG.replace(fabric=fab), SSD, wl, rounds=48)
        lat[name] = np.asarray(out.metrics.tenant_avg_e2e_us())
    assert lat["wfq"][0] < 0.4 * lat["fifo"][0]
    assert np.isfinite(lat["wfq"][1]) and lat["wfq"][1] > 0


def test_multitenant_metrics_account_every_completion():
    wl = workloads.MultiTenant(io_depth=16, tenant_read_frac=(1.0, 0.0))
    out = engine.simulate(CFG, SSD, wl, rounds=12)
    tc = np.asarray(out.metrics.tenant_completed)
    assert tc.shape == (2,)
    assert (tc > 0).all()
    assert float(np.sum(tc)) == pytest.approx(
        float(out.metrics.completed), rel=1e-6
    )
    # Per-tenant opcode mix: class 0 is all reads, class 1 all writes.
    ids = jnp.arange(64, dtype=jnp.int32)
    assert not np.asarray(wl.opcode(ids, 0, tenant=jnp.zeros_like(ids))).any()
    assert np.asarray(wl.opcode(ids, 0, tenant=jnp.ones_like(ids))).all()


# ---------------------------------------------------------------------------
# Satellite: replica routing on local arrays (device-side busy signal).
# ---------------------------------------------------------------------------

def test_replica_read_balances_on_local_array():
    """All blocks homed on drive 0 of a *local* 4-drive array: replicas
    spread the batch over the idle drives and cut the makespan (the
    wire cursors are flat 0 here — the device-side signal must carry)."""
    m, n = 4, 256
    client = StorageClient(SSD, EngineConfig(num_units=4, fetch_width=64))
    flash = _flash_store()
    skew = ((jnp.arange(n, dtype=jnp.int32) * 13) % SSD.num_blocks) \
        // m * m
    state = client.init_array_state(m)
    _, _, d1 = client.read_replicated(
        state, flash, skew, jnp.float32(0), replicas=1
    )
    _, _, dm = client.read_replicated(
        state, flash, skew, jnp.float32(0), replicas=m
    )
    assert float(jnp.max(dm)) < 0.6 * float(jnp.max(d1))


def test_replica_read_avoids_busy_local_drive():
    """Regression for the wire-cursor-only load signal: after a heavy
    batch lands on drive 0, replicated reads of blocks homed there must
    route to the idle replica drive instead of splitting evenly — the
    old rx_busy seed stayed 0 on local arrays and was blind to it."""
    m, nburst, nrep = 4, 512, 64
    client = StorageClient(SSD, EngineConfig(num_units=4, fetch_width=64))
    flash = _flash_store()
    state = client.init_array_state(m)

    # Load drive 0 only (other drives get invalid slots).
    lba = jnp.broadcast_to(
        (jnp.arange(nburst, dtype=jnp.int32) * 7) % SSD.num_blocks,
        (m, nburst),
    )
    valid = jnp.zeros((m, nburst), bool).at[0].set(True)
    state, _, d_burst = client.read_array(
        state, flash, lba, jnp.float32(0), valid, with_data=False
    )
    burst_makespan = float(jnp.max(d_burst))

    # Blocks homed on drive 0, replicas on {0, 1}: the fix routes them
    # to idle drive 1, so they finish long before the backlog drains.
    homed0 = (jnp.arange(nrep, dtype=jnp.int32) * m) % SSD.num_blocks
    _, _, d_rep = client.read_replicated(
        state, flash, homed0, jnp.float32(0), replicas=2
    )
    assert float(jnp.max(d_rep)) < 0.5 * burst_makespan


def test_client_parity_zero_cost_wire_replicated_and_writes():
    """Local array == remote array behind a free wire, bit-exactly, on
    the replica-routing path and the write path (the routing signal is
    the same device-side load in both)."""
    m, n = 4, 128
    flash = _flash_store()
    cfg = EngineConfig(num_units=4, fetch_width=64)
    lba = (jnp.arange(n, dtype=jnp.int32) * 13) % SSD.num_blocks
    local = StorageClient(SSD, cfg)
    remote = StorageClient(SSD, cfg.replace(fabric=FabricConfig(remote=True)))
    _, _, dl = local.read_replicated(
        local.init_array_state(m), flash, lba, jnp.float32(0), replicas=2
    )
    _, _, dr = remote.read_replicated(
        remote.init_array_state(m), flash, lba, jnp.float32(0), replicas=2
    )
    np.testing.assert_array_equal(np.asarray(dl), np.asarray(dr))

    data = jnp.ones((n, flash.shape[1]), flash.dtype)
    _, _, wl_done = local.write(
        local.init_state(), flash, data, lba, jnp.float32(0)
    )
    _, _, wr_done = remote.write(
        remote.init_state(), flash, data, lba, jnp.float32(0)
    )
    np.testing.assert_array_equal(np.asarray(wl_done), np.asarray(wr_done))
