"""Timing-model correctness: aggregated == per-request, exactly.

The paper's aggregated update must preserve the baseline semantics ("assuming
back-to-back scheduling of requests on their target instances", §IV-D). Our
segmented-(max,+)-scan closed form is exact, so we property-test equality
against the sequential scan reference under hypothesis-generated workloads.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep"
)
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timing
from repro.core.types import RequestBatch, SSDConfig, TimingState


def make_batch(arrival, lba, valid):
    n = len(arrival)
    z = jnp.zeros((n,), jnp.int32)
    return RequestBatch(
        arrival=jnp.asarray(arrival, jnp.float32),
        sq_id=z, slot=z, opcode=z,
        lba=jnp.asarray(lba, jnp.int32),
        nblocks=jnp.ones((n,), jnp.int32),
        buf_id=z,
        req_id=jnp.arange(n, dtype=jnp.int32),
        valid=jnp.asarray(valid, bool),
    )


@st.composite
def workloads(draw):
    n = draw(st.integers(min_value=1, max_value=96))
    k = draw(st.sampled_from([1, 2, 4, 8, 16]))
    arrival = draw(
        st.lists(
            st.floats(min_value=0.0, max_value=1e4, width=32, allow_subnormal=False),
            min_size=n, max_size=n,
        )
    )
    lba = draw(st.lists(st.integers(0, 2**20 - 1), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    busy0 = draw(
        st.lists(
            st.floats(min_value=0.0, max_value=5e3, width=32, allow_subnormal=False),
            min_size=k, max_size=k,
        )
    )
    t_max = draw(st.sampled_from([1e5, 2.47e6, 1e7, 4e7]))
    return arrival, lba, valid, busy0, k, t_max


@hypothesis.given(workloads())
@hypothesis.settings(max_examples=30, deadline=None)
def test_aggregated_matches_per_request(w):
    arrival, lba, valid, busy0, k, t_max = w
    ssd = SSDConfig(t_max_iops=t_max, n_instances=k)
    batch = make_batch(arrival, lba, valid)
    st0 = TimingState(jnp.asarray(busy0, jnp.float32), jnp.int32(0))

    s_ref, c_ref = timing.per_request_update(st0, batch, ssd)
    s_agg, c_agg = timing.aggregated_update(st0, batch, ssd)

    np.testing.assert_allclose(
        np.asarray(c_agg), np.asarray(c_ref), rtol=1e-5, atol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(s_agg.busy_until), np.asarray(s_ref.busy_until),
        rtol=1e-5, atol=1e-2,
    )


def test_low_load_latency_floor():
    """Under no contention, latency == L_min exactly (paper Fig. 2b)."""
    ssd = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64)
    # One request per instance (round-robin), far apart in time ⇒ no queueing.
    arrival = jnp.arange(64, dtype=jnp.float32) * 1e4
    lba = jnp.arange(64, dtype=jnp.int32)
    batch = make_batch(arrival, lba, jnp.ones(64, bool))
    _, comp = timing.aggregated_update(TimingState.init(64), batch, ssd)
    lat = np.asarray(comp - arrival)
    np.testing.assert_allclose(lat, 50.0, atol=1e-2)


def test_throughput_saturates_at_tmax():
    """A huge simultaneous burst completes at ~T_max aggregate IOPS."""
    ssd = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64)
    n = 8192
    arrival = jnp.zeros((n,), jnp.float32)
    lba = jnp.arange(n, dtype=jnp.int32) * 97
    batch = make_batch(arrival, lba, jnp.ones(n, bool))
    _, comp = timing.aggregated_update(TimingState.init(64), batch, ssd)
    span_s = float(jnp.max(comp)) * 1e-6
    iops = n / span_s
    # Round-robin assignment load-balances exactly ⇒ tight tolerance.
    assert iops == pytest.approx(2.47e6, rel=0.02)


def test_invalid_rows_do_not_touch_state():
    ssd = SSDConfig(n_instances=8)
    batch = make_batch([5.0, 7.0], [3, 4], [False, False])
    st0 = TimingState(jnp.arange(8, dtype=jnp.float32), jnp.int32(0))
    s1, comp = timing.aggregated_update(st0, batch, ssd)
    np.testing.assert_array_equal(
        np.asarray(s1.busy_until), np.asarray(st0.busy_until)
    )
    np.testing.assert_array_equal(np.asarray(comp), np.zeros(2))


def test_batch_split_equivalence():
    """Processing one batch == processing it as two half batches in order."""
    ssd = SSDConfig(t_max_iops=1e6, n_instances=4)
    n = 64
    rng = np.random.default_rng(0)
    arrival = np.sort(rng.uniform(0, 100, n)).astype(np.float32)
    lba = rng.integers(0, 1 << 16, n)
    full = make_batch(arrival, lba, np.ones(n, bool))
    st0 = TimingState.init(4)
    s_full, c_full = timing.aggregated_update(st0, full, ssd)

    h1 = make_batch(arrival[: n // 2], lba[: n // 2], np.ones(n // 2, bool))
    h2 = make_batch(arrival[n // 2:], lba[n // 2:], np.ones(n // 2, bool))
    s_a, c_a = timing.aggregated_update(st0, h1, ssd)
    s_b, c_b = timing.aggregated_update(s_a, h2, ssd)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(c_a), np.asarray(c_b)]),
        np.asarray(c_full), rtol=1e-5, atol=1e-2,
    )
    np.testing.assert_allclose(
        np.asarray(s_b.busy_until), np.asarray(s_full.busy_until),
        rtol=1e-5, atol=1e-2,
    )
