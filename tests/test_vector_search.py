"""Vector-search case study: recall correctness + IOPS-dependent QPS."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import vector_search as vs
from repro.core.types import EngineConfig, SSDConfig


def test_graph_index_is_knn():
    cfg = vs.SearchConfig(dim=16, degree=4)
    vecs, graph = vs.build_index(jax.random.PRNGKey(0), 128, cfg)
    # Verify one row against brute force.
    d = np.sum((np.asarray(vecs) - np.asarray(vecs[7])) ** 2, axis=1)
    d[7] = np.inf
    expect = set(np.argsort(d)[:4].tolist())
    assert set(np.asarray(graph[7]).tolist()) == expect


def test_search_reaches_high_recall():
    out = vs.case_study(n=1024, batch=16, width=4, iterations=24,
                        t_max_iops=2.5e6)
    assert out["recall"] >= 0.85, out["recall"]


def test_qps_scales_with_iops_at_large_batch():
    """Paper Fig. 16a: at batch 64+, 16x IOPS gives substantial speedup."""
    slow = vs.case_study(n=1024, batch=64, width=4, t_max_iops=2.5e6)
    fast = vs.case_study(n=1024, batch=64, width=4, t_max_iops=40e6)
    assert fast["qps"] > 3 * slow["qps"], (slow["qps"], fast["qps"])
    # Recall must not degrade with the faster device (same algorithm).
    assert abs(fast["recall"] - slow["recall"]) < 0.05


def test_qps_insensitive_to_iops_at_tiny_batch():
    """Paper Fig. 16a: batch 4 cannot generate enough parallel I/O."""
    slow = vs.case_study(n=1024, batch=4, width=2, t_max_iops=2.5e6)
    fast = vs.case_study(n=1024, batch=4, width=2, t_max_iops=40e6)
    ratio = fast["qps"] / slow["qps"]
    assert ratio < 2.0, ratio


def test_wider_beam_improves_recall_per_iteration():
    narrow = vs.case_study(n=1024, batch=16, width=1, iterations=12)
    wide = vs.case_study(n=1024, batch=16, width=8, iterations=12)
    assert wide["recall"] >= narrow["recall"]


def test_multi_device_array_speeds_up_io_bound_search():
    """Striping fetches over a 4-drive array relieves an I/O-bound search."""
    solo = vs.case_study(n=1024, batch=64, width=4, t_max_iops=1e6)
    arr = vs.case_study(n=1024, batch=64, width=4, t_max_iops=1e6,
                        num_devices=4)
    assert arr["qps"] > 1.5 * solo["qps"], (solo["qps"], arr["qps"])
    assert arr["recall"] >= 0.8
