"""Workload-generator unit + integration tests (all four generators)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads
from repro.core import engine
from repro.core.types import EngineConfig, SSDConfig, WorkloadConfig

SSD = SSDConfig(t_max_iops=2.47e6, l_min_us=50.0, n_instances=64,
                num_blocks=1 << 12)
CFG = EngineConfig(num_sqs=8, sq_depth=256, fetch_width=32, num_units=4,
                   emulate_data=False, num_bufs=512)


def test_closed_loop_matches_legacy_workload_config():
    """WorkloadConfig is adapted to ClosedLoop with identical behavior."""
    legacy = engine.simulate(CFG, SSD, WorkloadConfig(io_depth=32), rounds=24)
    new = engine.simulate(
        CFG, SSD, workloads.ClosedLoop(io_depth=32), rounds=24
    )
    assert float(legacy.metrics.completed) == float(new.metrics.completed)
    np.testing.assert_allclose(
        float(legacy.metrics.sum_e2e), float(new.metrics.sum_e2e), rtol=1e-6
    )


def test_poisson_gap_mean():
    """Exponential inter-arrival samples match the configured rate."""
    wl = workloads.PoissonOpenLoop(io_depth=64, rate_iops=1e6)
    gaps = wl.gap_us(jnp.arange(200_000), CFG)
    want = CFG.num_sqs / 1e6 * 1e6  # per-SQ mean gap in us
    assert float(gaps.mean()) == pytest.approx(want, rel=0.02)
    # Exponential: std == mean.
    assert float(gaps.std()) == pytest.approx(want, rel=0.05)


def test_poisson_open_loop_sustains_offered_rate():
    """Below device saturation the open loop delivers ~rate_iops."""
    wl = workloads.PoissonOpenLoop(io_depth=64, rate_iops=1e6)
    st = engine.simulate(CFG, SSD, wl, rounds=256)
    assert float(st.metrics.iops()) == pytest.approx(1e6, rel=0.15)


def test_poisson_open_loop_overload_blows_up_latency():
    """Past saturation: throughput caps at T_max, latency grows unboundedly
    (the open-loop signature a closed loop cannot produce)."""
    wl = workloads.PoissonOpenLoop(io_depth=64, rate_iops=4e6)
    st = engine.simulate(CFG, SSD, wl, rounds=256)
    assert float(st.metrics.iops()) == pytest.approx(SSD.t_max_iops, rel=0.1)
    assert float(st.metrics.avg_e2e_us()) > 5 * SSD.l_min_us
    assert float(st.metrics.p99_us()) > float(st.metrics.p50_us())


def test_zipf_skew_concentrates_mass():
    """theta=0.9 puts most accesses on the lowest 10% of addresses."""
    ids = jnp.arange(100_000)
    hot = workloads.ZipfClosedLoop(theta=0.9).address(ids, SSD)
    uni = workloads.ZipfClosedLoop(theta=0.0).address(ids, SSD)
    cut = SSD.num_blocks // 10
    hot_frac = float(jnp.mean((hot < cut).astype(jnp.float32)))
    uni_frac = float(jnp.mean((uni < cut).astype(jnp.float32)))
    assert hot_frac > 0.7, hot_frac
    assert uni_frac == pytest.approx(0.1, abs=0.02)
    assert int(hot.max()) < SSD.num_blocks


def test_zipf_runs_through_engine_and_hurts_lba_hash_routing():
    """Skewed addresses + address-hash routing underperform round-robin
    (the channel-imbalance sensitivity the generator exists for)."""
    wl = workloads.ZipfClosedLoop(io_depth=64, theta=0.95)
    rr = engine.simulate(CFG, SSD, wl, rounds=48)
    hashed = engine.simulate(
        CFG, SSD.replace(routing="lba_hash"), wl, rounds=48
    )
    assert float(rr.metrics.completed) > 0
    assert float(hashed.metrics.iops()) < float(rr.metrics.iops())


def test_trace_replay_round_trip():
    """Trace entries survive the ring round trip exactly and all complete."""
    t = 512
    rng = np.random.RandomState(0)
    times = np.sort(rng.uniform(0, 400.0, t).astype(np.float32))
    lbas = rng.randint(0, SSD.num_blocks, t).astype(np.int32)
    ops = (rng.uniform(size=t) < 0.2).astype(np.int32)
    wl = workloads.TraceReplay.from_trace(times, lbas, ops, CFG)
    assert wl.num_requests == t

    # Round trip: prefill -> flatten valid entries -> original trace order.
    pre = wl.prefill(CFG, SSD)
    sub = np.asarray(pre.submit)[np.asarray(pre.valid)]
    lb = np.asarray(pre.lba)[np.asarray(pre.valid)]
    op = np.asarray(pre.opcode)[np.asarray(pre.valid)]
    order = np.argsort(sub, kind="stable")
    np.testing.assert_allclose(sub[order], times, rtol=1e-6)
    np.testing.assert_array_equal(lb[order], lbas)
    np.testing.assert_array_equal(op[order], ops)

    # Replay completes every request exactly once, then the rings drain.
    st = engine.simulate(CFG, SSD, wl, rounds=96)
    assert float(st.metrics.completed) == t
    assert int(np.asarray(st.rings.tail - st.rings.head).sum()) == 0


def test_trace_replay_stripes_across_array_drives():
    """Regression: an M-drive array replays the trace *striped* (drive d
    gets time-sorted rows i % M == d, arrival times preserved) — per-
    drive completions sum to the trace length, not M times it."""
    t, m = 500, 3  # deliberately not divisible by M
    rng = np.random.RandomState(1)
    times = np.sort(rng.uniform(0, 400.0, t).astype(np.float32))
    lbas = rng.randint(0, SSD.num_blocks, t).astype(np.int32)
    wl = workloads.TraceReplay.from_trace(
        times, lbas, np.zeros(t), CFG
    )
    arr = engine.simulate(CFG, SSD, wl, rounds=96, num_devices=m)
    per_drive = np.asarray(arr.metrics.completed)
    assert per_drive.sum() == t, per_drive
    # Round-robin striping is balanced to within one row.
    assert per_drive.max() - per_drive.min() <= 1
    # Every stripe preserves its rows' arrival times: the earliest
    # submit seen by drive d is trace row d's timestamp.
    np.testing.assert_allclose(
        np.asarray(arr.metrics.first_submit), times[:m], rtol=1e-6
    )


def test_trace_shard_masks_partition_the_trace():
    """The per-drive prefill masks are disjoint and cover the trace."""
    t, m = 128, 4
    wl = workloads.TraceReplay.from_trace(
        np.arange(t, dtype=np.float32), np.zeros(t), np.zeros(t), CFG
    ).sharded(m)
    masks = [np.asarray(wl.prefill(CFG, SSD, salt=d).valid) for d in range(m)]
    total = np.zeros_like(masks[0], dtype=int)
    for mk in masks:
        total += mk.astype(int)
    base = np.asarray(workloads.TraceReplay.from_trace(
        np.arange(t, dtype=np.float32), np.zeros(t), np.zeros(t), CFG
    ).prefill(CFG, SSD).valid).astype(int)
    np.testing.assert_array_equal(total, base)  # disjoint + covering


def test_trace_too_long_for_rings_raises():
    small = CFG.replace(sq_depth=4, fetch_width=4)
    with pytest.raises(ValueError, match="sq_depth"):
        workloads.TraceReplay.from_trace(
            np.arange(64.0), np.zeros(64), np.zeros(64), small
        )


def test_all_generators_run_through_simulate():
    """The acceptance sweep: every generator executes under jit."""
    gens = [
        workloads.ClosedLoop(io_depth=16),
        workloads.PoissonOpenLoop(io_depth=16, rate_iops=1e6),
        workloads.ZipfClosedLoop(io_depth=16, theta=0.8),
        workloads.TraceReplay.from_trace(
            np.arange(128.0), np.arange(128) % SSD.num_blocks,
            np.zeros(128), CFG,
        ),
    ]
    for wl in gens:
        st = engine.simulate(CFG, SSD, wl, rounds=24)
        assert float(st.metrics.completed) > 0, type(wl).__name__
