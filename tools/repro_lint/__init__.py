"""repro-lint: repo-specific static analysis for the SwarmIO emulator.

The emulator's headline contract — every optimization is *bit-exact in
virtual time* — keeps being threatened by the same defect classes
(PRs 6-9): weak-typed pytree leaves that silently retrace jit programs,
FMA-contraction drift when a pinned float expression tree is
reassociated, and JAX's silent out-of-bounds scatter/gather semantics
corrupting ring permutations without an error. This package enforces
those invariants as lint rules instead of reviewer vigilance:

  RL001  weak-typed pytree leaf — bare python ``int``/``float`` literals
         (or module constants bound to them) passed directly to a
         registered pytree's constructor inside ``zero``/``init``/
         ``empty`` (the PR-8 ``Metrics.zero`` retrace bug class).
  RL002  pinned-expression fingerprint — ``# repro-lint: pinned-expr
         <name>`` fenced regions get a normalized-AST fingerprint
         checked against ``tools/repro_lint/pinned.lock``; any
         reassociation fails lint until regenerated with
         ``--update-lock``.
  RL003  sort discipline — no raw ``lax.sort``/``jnp.sort``/
         ``jnp.argsort`` outside ``core/segops.py``; everything routes
         through ``SortPlan``/``segops.stable_argsort``.
  RL004  scatter/gather bounds mode — every ``.at[...].set/add`` and
         ``jnp.take`` under ``core/`` must pass an explicit ``mode=``
         so silent OOB clamping is an opt-in decision, not a default.
  RL005  jit-boundary hygiene — no ``time.time``/``np.random``/host
         callbacks in functions reachable from ``make_runner`` /
         ``DevicePipeline.process``.
  RL006  deprecated-path ban — ``_fetch_direct``/``_submit_direct``
         referenced outside ``core/device.py`` and ``tests/``.

Usage::

    python -m tools.repro_lint src/            # exit 1 on violations
    python -m tools.repro_lint src/ --json     # machine-readable output
    python -m tools.repro_lint src/ --update-lock   # re-pin RL002

Per-line suppression: ``# repro-lint: disable=RL004`` (comma-separated
rule ids, or ``all``) on the flagged line or the line above it.
"""
from tools.repro_lint.engine import (  # noqa: F401
    Violation,
    lint_paths,
    lint_source,
)
from tools.repro_lint.pinning import (  # noqa: F401
    fingerprint_source,
    load_lock,
)
