"""CLI: ``python -m tools.repro_lint [paths...] [--json] [--update-lock]``.

Exit codes: 0 clean, 1 violations found, 2 usage/setup error.
"""
from __future__ import annotations

import argparse
import json
import sys

from tools.repro_lint import pinning
from tools.repro_lint.engine import lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="repo-specific static analysis (rules RL001-RL006); "
                    "see tools/repro_lint/__init__.py for the rule table",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON output")
    ap.add_argument("--update-lock", action="store_true",
                    help="regenerate the RL002 pinned-expression lockfile "
                         "from the scanned tree instead of checking it")
    ap.add_argument("--lock", default=str(pinning.DEFAULT_LOCK),
                    help="path to the pin lockfile (default: "
                         "tools/repro_lint/pinned.lock)")
    args = ap.parse_args(argv)
    paths = args.paths or ["src"]

    violations, checked = lint_paths(
        paths, lock_path=args.lock, update_lock=args.update_lock
    )

    if args.as_json:
        print(json.dumps({
            "checked_files": checked,
            "violations": [v.to_json() for v in violations],
        }, indent=2))
    else:
        for v in violations:
            print(v.render())
        tail = "updated lock; " if args.update_lock else ""
        print(
            f"repro-lint: {tail}{checked} files checked, "
            f"{len(violations)} violation(s)"
        )
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
