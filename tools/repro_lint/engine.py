"""Lint driver: file walking, suppression comments, rule orchestration.

``lint_paths`` is the programmatic entry point the CLI and the tests
share; ``lint_source`` lints a single in-memory source string (fixture
tests). Suppression: ``# repro-lint: disable=RL004`` (comma-separated
ids, or ``all``) on the flagged line or the line directly above it.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

from tools.repro_lint import pinning
from tools.repro_lint.rules import (
    ProjectIndex,
    rule_rl005,
    run_per_file_rules,
)
from tools.repro_lint.violation import Violation

_DISABLE = re.compile(r"#\s*repro-lint:\s*disable=([\w,]+)")

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def collect_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_file():
            out.append(path)
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
    return sorted(set(out))


def _suppressed_rules(lines: Sequence[str], lineno: int) -> set:
    """Rule ids disabled for 1-based line ``lineno``."""
    rules: set = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _DISABLE.search(lines[ln - 1])
            if m:
                rules.update(
                    r.strip() for r in m.group(1).split(",") if r.strip()
                )
    return rules


def apply_suppressions(
    violations: Iterable[Violation], sources: Dict[str, str]
) -> List[Violation]:
    out: List[Violation] = []
    line_cache: Dict[str, List[str]] = {}
    for v in violations:
        src = sources.get(v.path)
        if src is not None:
            if v.path not in line_cache:
                line_cache[v.path] = src.splitlines()
            dis = _suppressed_rules(line_cache[v.path], v.line)
            if v.rule in dis or "all" in dis:
                continue
        out.append(v)
    return out


def lint_source(
    src: str,
    relpath: str = "<memory>",
    lock: Dict[str, str] | None = None,
) -> List[Violation]:
    """Lint one in-memory source file (per-file rules + RL005 + RL002).

    RL005 runs with a single-module index, so fixtures that define their
    own ``make_runner``/``DevicePipeline.process`` roots exercise the
    reachability rule in isolation. ``lock`` enables RL002 against the
    given pin map (``{}`` checks that every fence is unpinned; ``None``
    skips RL002 entirely).
    """
    violations: List[Violation] = []
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation(
            "PARSE", relpath, e.lineno or 1, e.offset or 0,
            f"syntax error: {e.msg}",
        )]
    violations.extend(run_per_file_rules(tree, relpath))
    index = ProjectIndex()
    index.add(relpath, tree)
    violations.extend(rule_rl005(index))
    if lock is not None:
        fps, fence_errs = pinning.extract_fences(src, relpath)
        violations.extend(fence_errs)
        violations.extend(pinning.check_pins(
            relpath, fps, lock, pinning.fence_lines(src)
        ))
    return sorted(apply_suppressions(violations, {relpath: src}))


def lint_paths(
    paths: Sequence[str],
    lock_path: Path | str = pinning.DEFAULT_LOCK,
    update_lock: bool = False,
) -> Tuple[List[Violation], int]:
    """Lint files/directories. Returns ``(violations, files_checked)``.

    ``update_lock=True`` regenerates the RL002 lockfile from the scanned
    tree (entries for unscanned files are preserved) instead of checking
    against it.
    """
    files = collect_files(paths)
    sources: Dict[str, str] = {}
    trees: Dict[str, ast.Module] = {}
    violations: List[Violation] = []
    index = ProjectIndex()

    for f in files:
        rel = f.as_posix()
        try:
            src = f.read_text(encoding="utf-8")
        except OSError as e:
            violations.append(Violation("PARSE", rel, 1, 0, str(e)))
            continue
        sources[rel] = src
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError as e:
            violations.append(Violation(
                "PARSE", rel, e.lineno or 1, e.offset or 0,
                f"syntax error: {e.msg}",
            ))
            continue
        trees[rel] = tree
        index.add(rel, tree)

    for rel, tree in trees.items():
        violations.extend(run_per_file_rules(tree, rel))
    violations.extend(rule_rl005(index))

    # RL002: fence fingerprints vs the committed lock.
    lock = pinning.load_lock(Path(lock_path))
    scanned_pins: Dict[str, str] = {}
    for rel, src in sources.items():
        fps, fence_errs = pinning.extract_fences(src, rel)
        violations.extend(fence_errs)
        for name, fp in fps.items():
            scanned_pins[f"{rel}::{name}"] = fp
        if not update_lock:
            violations.extend(pinning.check_pins(
                rel, fps, lock, pinning.fence_lines(src)
            ))
    if update_lock:
        kept = {
            k: v for k, v in lock.items()
            if k.split("::", 1)[0] not in sources
        }
        kept.update(scanned_pins)
        pinning.save_lock(kept, Path(lock_path))

    return sorted(apply_suppressions(violations, sources)), len(files)
