"""RL002: pinned-expression fingerprints.

A fenced region

    # repro-lint: pinned-expr <name>
    ...protected statements...
    # repro-lint: end-pinned-expr

is fingerprinted by the sha256 of its *normalized AST dump* — so
whitespace, comments, and line wrapping are free to change, but any
reassociation of the protected float expression tree (the PR-8/9
FMA-contraction hazard: algebraically equal forms can compile one ULP
apart) changes the fingerprint and fails lint until the lock is
intentionally regenerated with ``--update-lock``.

The lock lives at ``tools/repro_lint/pinned.lock`` (JSON), keyed by
``<posix relpath>::<fence name>``.
"""
from __future__ import annotations

import ast
import hashlib
import json
import re
import textwrap
from pathlib import Path
from typing import Dict, List, Tuple

from tools.repro_lint.violation import Violation

DEFAULT_LOCK = Path(__file__).resolve().parent / "pinned.lock"

_OPEN = re.compile(r"#\s*repro-lint:\s*pinned-expr\s+([\w./-]+)\s*$")
_CLOSE = re.compile(r"#\s*repro-lint:\s*end-pinned-expr\s*$")


def fingerprint_source(src: str) -> str:
    """Normalized-AST fingerprint of a python source fragment.

    The fragment is parsed inside a dummy enclosing function (so fences
    may legally contain ``return``/``yield``) and fingerprinted from the
    AST dump — whitespace- and comment-insensitive, reassociation-
    sensitive. Raises ``SyntaxError`` if the fragment does not enclose
    whole statements.
    """
    body = textwrap.indent(textwrap.dedent(src), "    ")
    tree = ast.parse("def __pinned__():\n" + body)
    dump = ast.dump(tree, annotate_fields=True, include_attributes=False)
    return "sha256:" + hashlib.sha256(dump.encode("utf-8")).hexdigest()


def extract_fences(
    src: str, relpath: str
) -> Tuple[Dict[str, str], List[Violation]]:
    """Scan one file for pinned-expr fences.

    Returns ``(fingerprints, violations)`` where ``fingerprints`` maps
    fence name -> fingerprint and ``violations`` carries malformed-fence
    errors (unterminated, duplicate name, unparseable region).
    """
    lines = src.splitlines()
    fps: Dict[str, str] = {}
    out: List[Violation] = []
    open_name = None
    open_line = 0
    region: List[str] = []
    for i, line in enumerate(lines, start=1):
        m = _OPEN.search(line)
        if m:
            if open_name is not None:
                out.append(Violation(
                    "RL002", relpath, i, 0,
                    f"pinned-expr {m.group(1)!r} opened inside unclosed "
                    f"fence {open_name!r} (line {open_line})",
                ))
                continue
            open_name, open_line, region = m.group(1), i, []
            continue
        if _CLOSE.search(line):
            if open_name is None:
                out.append(Violation(
                    "RL002", relpath, i, 0,
                    "end-pinned-expr with no matching pinned-expr fence",
                ))
                continue
            if open_name in fps:
                out.append(Violation(
                    "RL002", relpath, open_line, 0,
                    f"duplicate pinned-expr name {open_name!r}",
                ))
            else:
                try:
                    fps[open_name] = fingerprint_source("\n".join(region))
                except SyntaxError as e:
                    out.append(Violation(
                        "RL002", relpath, open_line, 0,
                        f"pinned-expr {open_name!r} region does not parse "
                        f"as standalone statements: {e.msg}",
                    ))
            open_name = None
            continue
        if open_name is not None:
            region.append(line)
    if open_name is not None:
        out.append(Violation(
            "RL002", relpath, open_line, 0,
            f"unterminated pinned-expr fence {open_name!r} "
            "(missing '# repro-lint: end-pinned-expr')",
        ))
    return fps, out


def load_lock(lock_path: Path = DEFAULT_LOCK) -> Dict[str, str]:
    """Load the committed pin lockfile ({} if absent)."""
    if not Path(lock_path).exists():
        return {}
    data = json.loads(Path(lock_path).read_text())
    return dict(data.get("pins", {}))


def save_lock(pins: Dict[str, str], lock_path: Path = DEFAULT_LOCK) -> None:
    payload = {"version": 1, "pins": dict(sorted(pins.items()))}
    Path(lock_path).write_text(json.dumps(payload, indent=2) + "\n")


def check_pins(
    relpath: str,
    fps: Dict[str, str],
    lock: Dict[str, str],
    first_fence_line: Dict[str, int] | None = None,
) -> List[Violation]:
    """Compare one file's fence fingerprints against the lock."""
    out: List[Violation] = []
    lines = first_fence_line or {}
    for name, fp in fps.items():
        key = f"{relpath}::{name}"
        want = lock.get(key)
        line = lines.get(name, 1)
        if want is None:
            out.append(Violation(
                "RL002", relpath, line, 0,
                f"pinned-expr {name!r} has no lock entry — run "
                "`python -m tools.repro_lint --update-lock` to pin it",
            ))
        elif want != fp:
            out.append(Violation(
                "RL002", relpath, line, 0,
                f"pinned-expr {name!r} changed (expression tree was "
                "reassociated or edited): FMA contraction is "
                "program-context-dependent, so algebraically equal forms "
                "can drift 1 ULP. If intentional, regenerate with "
                "--update-lock and re-run the bit-exactness parity tests",
            ))
    prefix = f"{relpath}::"
    for key in lock:
        if key.startswith(prefix) and key[len(prefix):] not in fps:
            out.append(Violation(
                "RL002", relpath, 1, 0,
                f"lock entry {key!r} has no matching pinned-expr fence "
                "(fence removed?) — regenerate with --update-lock if "
                "intentional",
            ))
    return out


def fence_lines(src: str) -> Dict[str, int]:
    """Map fence name -> opening line number (for diagnostics)."""
    out: Dict[str, int] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _OPEN.search(line)
        if m and m.group(1) not in out:
            out[m.group(1)] = i
    return out
