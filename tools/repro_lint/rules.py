"""The per-file and whole-project AST rules (RL001, RL003-RL006).

All rules work on plain ``ast`` trees — no imports of the linted code,
so linting never executes (or even requires) jax. RL002 lives in
``pinning.py`` (it fingerprints source regions, not node patterns).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from tools.repro_lint.violation import Violation

# Modules whose ``sort``/``argsort`` attributes RL003 bans (after import-
# alias resolution): raw sorts bypass the SortPlan reuse discipline and
# the stable-sort bit-exactness contract.
_SORT_MODULES = {"jax.numpy", "numpy", "jax.lax"}
_SORT_ATTRS = {"sort", "argsort", "lexsort", "msort", "sort_complex"}

# RL005: dotted-name prefixes that must not be reachable from the jit
# entry points. Matched against the *resolved* dotted call target
# (import aliases expanded), longest-prefix wins.
_BANNED_CALLS = {
    "time.time": "wall-clock read inside a jit-traced function "
                 "(traces once, then is a baked-in constant)",
    "time.perf_counter": "wall-clock read inside a jit-traced function",
    "time.monotonic": "wall-clock read inside a jit-traced function",
    "time.process_time": "wall-clock read inside a jit-traced function",
    "numpy.random": "host-side RNG inside a jit-traced function (use "
                    "segops.hash_u32 counter-based randomness)",
    "random.": "host-side RNG inside a jit-traced function (use "
               "segops.hash_u32 counter-based randomness)",
    "jax.pure_callback": "host callback on the jit hot path",
    "jax.experimental.io_callback": "host callback on the jit hot path",
    "jax.debug.callback": "host callback on the jit hot path",
}

# RL005 roots: the jit entry points whose transitive callees must stay
# trace-pure.
_ROOT_FUNCTIONS = {"make_runner", "make_array_runner"}
_ROOT_METHODS = {("DevicePipeline", "process")}

_DEPRECATED = {"_fetch_direct", "_submit_direct"}

_PYTREE_CTOR_METHODS = {"zero", "init", "empty"}


def _dotted(node: ast.AST) -> str | None:
    """Render an attribute chain as a dotted name ('' when not one)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local name -> fully qualified dotted target.

    ``import jax.numpy as jnp`` -> {"jnp": "jax.numpy"};
    ``from repro.core import timing`` -> {"timing": "repro.core.timing"};
    ``from time import perf_counter`` -> {"perf_counter":
    "time.perf_counter"}.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
                if a.asname is None and "." in a.name:
                    # `import jax.numpy` binds `jax`, but the dotted use
                    # sites resolve through the root name anyway.
                    out[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _resolve(dotted: str, aliases: Dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def _is_weak_number(node: ast.AST, weak_consts: Set[str]) -> bool:
    """A bare python numeric literal (or a Name bound to one)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float)) and not isinstance(
            node.value, bool
        )
    if isinstance(node, ast.UnaryOp) and isinstance(
        node.op, (ast.USub, ast.UAdd)
    ):
        return _is_weak_number(node.operand, weak_consts)
    if isinstance(node, ast.Name):
        return node.id in weak_consts
    return False


def _module_numeric_consts(tree: ast.Module) -> Set[str]:
    """Module-level NAME = <numeric literal> bindings (e.g. FAR = 3e38)."""
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) and _is_weak_number(
            node.value, set()
        ):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if _is_weak_number(node.value, set()) and isinstance(
                node.target, ast.Name
            ):
                out.add(node.target.id)
    return out


def _registered_pytree_classes(tree: ast.Module) -> Set[str]:
    """Class names registered as jax pytrees in this module.

    Covers the decorator form (``@jax.tree_util.register_dataclass``,
    ``@register_pytree_node_class``) and the module-level call form
    (``jax.tree_util.register_pytree_node(Cls, ...)``).
    """
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                d = _dotted(target) or ""
                if d.split(".")[-1] in (
                    "register_dataclass", "register_pytree_node_class",
                ):
                    names.add(node.name)
        elif isinstance(node, ast.Call):
            d = _dotted(node.func) or ""
            if d.split(".")[-1] in (
                "register_dataclass", "register_pytree_node",
            ) and node.args and isinstance(node.args[0], ast.Name):
                names.add(node.args[0].id)
    return names


def rule_rl001(tree: ast.Module, relpath: str) -> List[Violation]:
    """Weak-typed pytree leaf in a zero/init/empty constructor."""
    out: List[Violation] = []
    pytrees = _registered_pytree_classes(tree)
    if not pytrees:
        return out
    weak_consts = _module_numeric_consts(tree)
    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and cls.name in pytrees):
            continue
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name not in _PYTREE_CTOR_METHODS:
                continue
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                callee = call.func
                is_ctor = isinstance(callee, ast.Name) and (
                    callee.id == cls.name or callee.id == "cls"
                )
                if not is_ctor:
                    continue
                for arg in list(call.args) + [
                    kw.value for kw in call.keywords
                ]:
                    if _is_weak_number(arg, weak_consts):
                        out.append(Violation(
                            "RL001", relpath, arg.lineno, arg.col_offset,
                            f"weak-typed leaf in {cls.name}.{fn.name}: a "
                            "bare python number makes the pytree aval "
                            "weak-typed, so runner outputs mismatch "
                            "init-state avals and jit silently retraces "
                            "(the PR-8 Metrics.zero bug) — wrap it in "
                            "jnp.float32(...)/jnp.int32(...)",
                        ))
    return out


def rule_rl003(tree: ast.Module, relpath: str) -> List[Violation]:
    """Raw sort outside core/segops.py."""
    if relpath.replace("\\", "/").endswith("core/segops.py"):
        return []
    aliases = _import_aliases(tree)
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        if node.attr not in _SORT_ATTRS:
            continue
        base = _dotted(node.value)
        if base is None:
            continue
        if _resolve(base, aliases) in _SORT_MODULES:
            out.append(Violation(
                "RL003", relpath, node.lineno, node.col_offset,
                f"raw {base}.{node.attr} outside core/segops.py — route "
                "through segops.stable_argsort / SortPlan so sort "
                "stability and plan reuse stay centralized",
            ))
    return out


def rule_rl004(tree: ast.Module, relpath: str) -> List[Violation]:
    """Scatter/gather without an explicit mode= under core/."""
    p = relpath.replace("\\", "/")
    if "/core/" not in p and not p.startswith("core/"):
        return []
    aliases = _import_aliases(tree)
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        has_mode = any(kw.arg == "mode" for kw in node.keywords)
        f = node.func
        # x.at[idx].set(...) / .add / .max / .min / .mul
        if (
            isinstance(f, ast.Attribute)
            and f.attr in ("set", "add", "max", "min", "mul", "get")
            and isinstance(f.value, ast.Subscript)
            and isinstance(f.value.value, ast.Attribute)
            and f.value.value.attr == "at"
        ):
            if not has_mode:
                out.append(Violation(
                    "RL004", relpath, node.lineno, node.col_offset,
                    f".at[...].{f.attr}(...) without an explicit mode= — "
                    "JAX silently drops OOB scatter updates and clamps "
                    "OOB gathers, which corrupts ring/compaction "
                    "permutations without an error; make the bounds "
                    "behavior explicit (mode=\"drop\"/\"fill\"/"
                    "\"promise_in_bounds\")",
                ))
            continue
        # jnp.take(...)
        d = _dotted(f)
        if d is not None and _resolve(d, aliases) in (
            "jax.numpy.take", "numpy.take",
        ):
            if not has_mode:
                out.append(Violation(
                    "RL004", relpath, node.lineno, node.col_offset,
                    "jnp.take without an explicit mode= — OOB gathers "
                    "clamp silently; make the bounds behavior explicit",
                ))
    return out


def rule_rl006(tree: ast.Module, relpath: str) -> List[Violation]:
    """Deprecated direct-path use outside core/device.py and tests/."""
    p = relpath.replace("\\", "/")
    if p.endswith("core/device.py") or "tests/" in p or p.startswith(
        "tests"
    ):
        return []
    out: List[Violation] = []
    for node in ast.walk(tree):
        name = None
        if isinstance(node, ast.Attribute) and node.attr in _DEPRECATED:
            name = node.attr
        elif isinstance(node, ast.Name) and node.id in _DEPRECATED:
            name = node.id
        if name is not None:
            out.append(Violation(
                "RL006", relpath, node.lineno, node.col_offset,
                f"{name} is the test-only ring-less shortcut — "
                "production consumers go through StorageClient.submit / "
                "the SQ rings (see core/device.py docstring)",
            ))
    return out


# ---------------------------------------------------------------------------
# RL005: whole-project call-graph reachability.
# ---------------------------------------------------------------------------

class ProjectIndex:
    """Cross-module function/method index for reachability traversal."""

    def __init__(self) -> None:
        # module relpath -> (tree, aliases)
        self.modules: Dict[str, Tuple[ast.Module, Dict[str, str]]] = {}
        # (relpath, qualname) -> function node
        self.functions: Dict[Tuple[str, str], ast.AST] = {}
        # bare name -> [(relpath, qualname)] over-approximation index
        self.by_name: Dict[str, List[Tuple[str, str]]] = {}

    def add(self, relpath: str, tree: ast.Module) -> None:
        self.modules[relpath] = (tree, _import_aliases(tree))
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._register(relpath, node.name, node)
            elif isinstance(node, ast.ClassDef):
                for meth in node.body:
                    if isinstance(
                        meth, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        qual = f"{node.name}.{meth.name}"
                        self._register(relpath, qual, meth)

    def _register(self, relpath: str, qual: str, node: ast.AST) -> None:
        self.functions[(relpath, qual)] = node
        self.by_name.setdefault(qual.split(".")[-1], []).append(
            (relpath, qual)
        )

    # -- resolution ---------------------------------------------------------
    def resolve_call(
        self, relpath: str, call: ast.Call
    ) -> List[Tuple[str, str]]:
        """Possible (relpath, qualname) targets of a call (may be [])."""
        tree, aliases = self.modules[relpath]
        f = call.func
        if isinstance(f, ast.Name):
            # Same-module function, else a from-imported repro function.
            if (relpath, f.id) in self.functions:
                return [(relpath, f.id)]
            target = aliases.get(f.id)
            if target and target.startswith("repro."):
                return self._by_module_func(target)
            return []
        d = _dotted(f)
        if d is not None:
            head, _, rest = d.partition(".")
            base = aliases.get(head)
            if base and base.startswith("repro.") and rest:
                hit = self._by_module_func(f"{base}.{rest}")
                if hit:
                    return hit
        # Method / unknown-receiver call: over-approximate by bare name
        # (lint soundness beats precision here — false reachability can
        # only surface a real banned call somewhere in the repo).
        if isinstance(f, ast.Attribute):
            return list(self.by_name.get(f.attr, []))
        return []

    def _by_module_func(self, dotted: str) -> List[Tuple[str, str]]:
        mod, _, func = dotted.rpartition(".")
        suffix = mod.replace(".", "/") + ".py"
        return [
            (rp, func)
            for (rp, qual) in self.functions
            if qual == func and rp.replace("\\", "/").endswith(suffix)
        ]


def _roots(index: ProjectIndex) -> List[Tuple[str, str]]:
    roots: List[Tuple[str, str]] = []
    for (rp, qual) in index.functions:
        if qual in _ROOT_FUNCTIONS:
            roots.append((rp, qual))
        for cls, meth in _ROOT_METHODS:
            if qual == f"{cls}.{meth}":
                roots.append((rp, qual))
    return roots


def _banned_match(resolved: str) -> str | None:
    for prefix, why in _BANNED_CALLS.items():
        if prefix.endswith("."):
            if resolved.startswith(prefix) or resolved == prefix[:-1]:
                return why
        elif resolved == prefix or resolved.startswith(prefix + "."):
            return why
    return None


def rule_rl005(index: ProjectIndex) -> List[Violation]:
    """Host-side impurity reachable from the jit entry points."""
    out: List[Violation] = []
    seen_nodes: Set[Tuple[str, str]] = set()
    seen_violations: Set[Tuple[str, int, int]] = set()

    def visit(rp: str, qual: str, root: str) -> None:
        if (rp, qual) in seen_nodes:
            return
        seen_nodes.add((rp, qual))
        node = index.functions.get((rp, qual))
        if node is None:
            return
        _, aliases = index.modules[rp]
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            d = _dotted(call.func)
            if d is not None:
                why = _banned_match(_resolve(d, aliases))
                if why is not None:
                    key = (rp, call.lineno, call.col_offset)
                    if key not in seen_violations:
                        seen_violations.add(key)
                        out.append(Violation(
                            "RL005", rp, call.lineno, call.col_offset,
                            f"{d} reachable from {root}: {why}",
                        ))
                    continue
            for target in index.resolve_call(rp, call):
                visit(*target, root)

    for rp, qual in sorted(_roots(index)):
        visit(rp, qual, f"{rp}::{qual}")
    return out


PER_FILE_RULES = (rule_rl001, rule_rl003, rule_rl004, rule_rl006)


def run_per_file_rules(
    tree: ast.Module, relpath: str
) -> Iterable[Violation]:
    for rule in PER_FILE_RULES:
        yield from rule(tree, relpath)
