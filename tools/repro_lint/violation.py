"""The one shared datatype: a lint violation with a stable sort order."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    rule: str      # "RL001".."RL006", or "PARSE" for unreadable files
    path: str      # posix relpath as scanned
    line: int      # 1-based
    col: int       # 0-based
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)
